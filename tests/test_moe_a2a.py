"""Correctness of the shard_map all-to-all MoE vs the einsum oracle.

Runs in a subprocess so XLA can be forced to 4 host devices (the main test
process keeps the default 1-device config).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro import jax_compat
from repro.models.config import MoEConfig
from repro.models import moe as moe_mod
from repro.models.moe_a2a import moe_forward_a2a

mo = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
               capacity_factor=2.0)  # E/top_k: drop-free
d = 16
params = moe_mod.init_moe(jax.random.key(0), d, mo)
x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)

ref, aux_ref = moe_mod.moe_forward(params, x, mo)

# AxisType/set_mesh only exist on newer jax; jax_compat degrades to a plain
# Mesh + physical `with mesh:` context on 0.4.x.
mesh = jax_compat.make_mesh((2, 2), ("data", "model"))
with mesh, jax_compat.set_mesh(mesh):
    got, aux = jax.jit(
        lambda p, xx: moe_forward_a2a(p, xx, mo)
    )(params, x)

np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
# aux definitions match (same f, p statistics)
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)

# gradient path through shard_map (train viability)
def loss(p):
    out, aux2 = moe_forward_a2a(p, x, mo)
    return jnp.sum(out**2) + 0.01 * aux2
with mesh, jax_compat.set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(params)
gn = sum(float(jnp.sum(jnp.abs(t))) for t in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("A2A_MOE_OK")
"""


@pytest.mark.parametrize("dummy", [0])
def test_a2a_matches_einsum_oracle(dummy):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "A2A_MOE_OK" in res.stdout, (
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    )
