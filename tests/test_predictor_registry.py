"""Tests for the predictor abstraction (Eq. 2) and model-pool dedup (Sec. 2.2.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import Predictor, PredictorSpec, TransformPipeline, deploy_predictor
from repro.core.registry import ModelNotDeployed, ModelPool
from repro.core.transforms import QuantileMap, posterior_correction, quantile_map


def _const_model(value: float):
    return lambda x: jnp.full(np.asarray(x).shape[:1], value, dtype=jnp.float32)


def _identity_qm(n=16):
    return QuantileMap.identity(n)


class TestModelPoolDedup:
    def test_incremental_ensemble_provisions_only_new_model(self):
        """The paper's Fig.-1 scenario: p1={m1,m2}, p2={m1,m2,m3} -> only m3
        is provisioned for p2 (marginal-cost deployment)."""
        pool = ModelPool()
        factories = {f"m{i}": (lambda i=i: _const_model(i / 10)) for i in (1, 2, 3)}
        costs = {"m1": 5.0, "m2": 5.0, "m3": 7.0}

        spec1 = PredictorSpec("p1", ("m1", "m2"), (0.18, 0.18), (1.0, 1.0), _identity_qm())
        assert pool.marginal_cost_of(spec1.model_names, costs) == 10.0
        p1 = deploy_predictor(spec1, pool, factories, costs)
        assert pool.provision_events == 2

        spec2 = PredictorSpec("p2", ("m1", "m2", "m3"), (0.18, 0.18, 0.02),
                              (1.0, 1.0, 1.0), _identity_qm())
        # marginal cost is only m3's
        assert pool.marginal_cost_of(spec2.model_names, costs) == 7.0
        p2 = deploy_predictor(spec2, pool, factories, costs)
        assert pool.provision_events == 3  # only m3 added
        assert pool.total_resource_cost() == 17.0

        # decommission p1: m1/m2 stay (referenced by p2)
        p1.release(pool)
        assert "m1" in pool and "m2" in pool
        p2.release(pool)
        assert pool.names() == ()

    def test_acquire_unknown_raises(self):
        with pytest.raises(ModelNotDeployed):
            ModelPool().acquire("ghost")

    def test_deploy_idempotent(self):
        pool = ModelPool()
        pool.deploy("m", _const_model(0.5))
        pool.deploy("m", _const_model(0.9))  # reused, not replaced
        assert pool.provision_events == 1
        assert pool.reuse_events == 1


class TestPredictorEq2:
    def test_single_model_skips_posterior_correction(self):
        """Paper Sec. 2.2.2: for |M|=1, p(x) = T^Q(m(x)) — no T^C, identity A."""
        pool = ModelPool()
        pool.deploy("m", _const_model(0.7))
        qs = jnp.linspace(0, 1, 16)
        qr = jnp.linspace(0, 1, 16) ** 0.5
        spec = PredictorSpec("p", ("m",), (0.05,), (1.0,), QuantileMap(qs, qr))
        p = Predictor(spec, pool)
        x = np.zeros((4, 3))
        out = np.asarray(p(x))
        expected = np.asarray(quantile_map(jnp.full((4,), 0.7), qs, qr))
        np.testing.assert_allclose(out, expected, rtol=1e-6)

    def test_ensemble_full_eq2(self):
        pool = ModelPool()
        pool.deploy("m1", _const_model(0.9))
        pool.deploy("m2", _const_model(0.4))
        qm = _identity_qm()
        spec = PredictorSpec("p", ("m1", "m2"), (0.18, 0.02), (1.0, 3.0), qm)
        p = Predictor(spec, pool)
        out = float(np.asarray(p(np.zeros((1, 2))))[0])
        c1 = float(posterior_correction(jnp.float32(0.9), 0.18))
        c2 = float(posterior_correction(jnp.float32(0.4), 0.02))
        expected = (1.0 * c1 + 3.0 * c2) / 4.0
        np.testing.assert_allclose(out, expected, rtol=1e-5)

    def test_pipeline_hot_swap_shares_models(self):
        """T^Q_v0 -> T^Q_v1 swap must not touch model handles (cheap update)."""
        pool = ModelPool()
        pool.deploy("m", _const_model(0.5))
        spec = PredictorSpec.single("p", "m", _identity_qm())
        p0 = Predictor(spec, pool)
        qs = jnp.linspace(0, 1, 16)
        new_pipe = p0.pipeline.with_quantile_map(QuantileMap(qs, qs**2))
        p1 = p0.with_updated_pipeline(new_pipe)
        assert p1._handles is p0._handles  # no re-provisioning
        assert float(p0(np.zeros((1, 1)))[0]) == pytest.approx(0.5, abs=1e-6)
        # 16-knot piecewise-linear approx of x^2 -> O((1/15)^2/4) interp error
        assert float(p1(np.zeros((1, 1)))[0]) == pytest.approx(0.25, abs=2e-3)

    def test_weight_update_adapts_without_retraining(self):
        """Sec. 2.3.2: adjusting aggregation weights = lightweight adaptation."""
        pool = ModelPool()
        pool.deploy("a", _const_model(0.2))
        pool.deploy("b", _const_model(0.8))
        spec = PredictorSpec("p", ("a", "b"), (1.0, 1.0), (1.0, 1.0), _identity_qm())
        p = Predictor(spec, pool)
        assert float(p(np.zeros((1, 1)))[0]) == pytest.approx(0.5, abs=1e-6)
        p2 = p.with_updated_pipeline(p.pipeline.with_weights(jnp.array([0.0, 1.0])))
        assert float(p2(np.zeros((1, 1)))[0]) == pytest.approx(0.8, abs=1e-6)

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            PredictorSpec("bad", ("m1", "m2"), (0.5,), (1.0, 1.0), _identity_qm())

    def test_raw_scores_shape(self):
        pool = ModelPool()
        pool.deploy("m1", _const_model(0.1))
        pool.deploy("m2", _const_model(0.2))
        spec = PredictorSpec("p", ("m1", "m2"), (1.0, 1.0), (1.0, 1.0), _identity_qm())
        p = Predictor(spec, pool)
        raw = p.raw_scores(np.zeros((6, 4)))
        assert raw.shape == (6, 2)
