"""Tests for quantile estimation + the Appendix-A sample-size bound."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantiles import (
    StreamingQuantileEstimator,
    alert_rate_rel_error,
    batch_quantiles,
    required_sample_size,
)


class TestSampleSize:
    def test_paper_formula(self):
        # n = z^2 (1-a) / (delta^2 a)
        a, d, z = 0.01, 0.2, 1.96
        n = required_sample_size(a, d, z)
        assert n == int(np.ceil(z * z * (1 - a) / (d * d * a)))

    def test_na_approx_z2_over_delta2(self):
        """Appendix A: substituting back gives n*a ~ z^2/delta^2 (~96 for
        z=1.96, delta=0.2), satisfying the normal-approximation condition."""
        for a in (0.001, 0.01, 0.1):
            n = required_sample_size(a, 0.2)
            assert n * a == pytest.approx((1.96 / 0.2) ** 2 * (1 - a), rel=0.01)

    def test_monotonicity(self):
        assert required_sample_size(0.001, 0.2) > required_sample_size(0.01, 0.2)
        assert required_sample_size(0.01, 0.1) > required_sample_size(0.01, 0.2)

    def test_inverse_roundtrip(self):
        a, n = 0.01, 100_000
        d = alert_rate_rel_error(a, n)
        assert required_sample_size(a, d) == pytest.approx(n, rel=0.01)

    def test_invalid_inputs(self):
        with pytest.raises(ValueError):
            required_sample_size(0.0, 0.1)
        with pytest.raises(ValueError):
            required_sample_size(0.5, 0.0)

    def test_empirical_coverage(self):
        """Monte-Carlo check of Appendix A: with n samples from Eq. 5, the
        realized alert rate deviates < delta·a from target ~95% of the time."""
        a, delta, z = 0.05, 0.25, 1.96
        n = required_sample_size(a, delta, z)
        rng = np.random.default_rng(0)
        hits = 0
        trials = 400
        for _ in range(trials):
            scores = rng.random(n)
            thr = np.quantile(scores, 1 - a)
            realized = np.mean(rng.random(200_000) > thr)
            if abs(realized - a) <= delta * a:
                hits += 1
        coverage = hits / trials
        assert coverage > 0.90, f"coverage {coverage} below nominal 95%"


class TestStreamingEstimator:
    def test_exact_below_capacity(self):
        rng = np.random.default_rng(1)
        data = rng.random(10_000)
        est = StreamingQuantileEstimator(capacity=16_384)
        est.update(data)
        q = est.quantiles(np.array([0.1, 0.5, 0.9]))
        np.testing.assert_allclose(q, np.quantile(data, [0.1, 0.5, 0.9]), atol=1e-12)

    def test_reservoir_unbiased_above_capacity(self):
        rng = np.random.default_rng(2)
        est = StreamingQuantileEstimator(capacity=8_192, seed=3)
        for _ in range(20):
            est.update(rng.beta(2, 5, 10_000))
        q = est.quantiles(np.array([0.25, 0.5, 0.75]))
        from scipy import stats
        true_q = stats.beta.ppf([0.25, 0.5, 0.75], 2, 5)
        np.testing.assert_allclose(q, true_q, atol=0.03)

    def test_ready_gating(self):
        est = StreamingQuantileEstimator(capacity=1024)
        assert not est.ready(alert_rate=0.01, rel_error=0.2)
        est.update(np.random.default_rng(0).random(required_sample_size(0.01, 0.2) + 1))
        assert est.ready(alert_rate=0.01, rel_error=0.2)

    def test_empty_raises(self):
        est = StreamingQuantileEstimator()
        with pytest.raises(ValueError):
            est.quantiles(np.array([0.5]))

    @given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_count_tracks_updates(self, n, seed):
        est = StreamingQuantileEstimator(capacity=256, seed=seed)
        est.update(np.random.default_rng(seed).random(n))
        assert est.count == n
        q = est.quantiles(np.array([0.0, 1.0]))
        assert q[0] <= q[1]


class TestBatchQuantiles:
    def test_monotone(self):
        rng = np.random.default_rng(4)
        levels, q = batch_quantiles(rng.random(1000), 65)
        assert (np.diff(q) >= 0).all()
        assert len(levels) == len(q) == 65
