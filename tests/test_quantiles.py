"""Tests for quantile estimation + the Appendix-A sample-size bound."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantiles import (
    StreamingQuantileEstimator,
    alert_rate_rel_error,
    batch_quantiles,
    merge_rank_error_bound,
    required_sample_size,
)


class TestSampleSize:
    def test_paper_formula(self):
        # n = z^2 (1-a) / (delta^2 a)
        a, d, z = 0.01, 0.2, 1.96
        n = required_sample_size(a, d, z)
        assert n == int(np.ceil(z * z * (1 - a) / (d * d * a)))

    def test_na_approx_z2_over_delta2(self):
        """Appendix A: substituting back gives n*a ~ z^2/delta^2 (~96 for
        z=1.96, delta=0.2), satisfying the normal-approximation condition."""
        for a in (0.001, 0.01, 0.1):
            n = required_sample_size(a, 0.2)
            assert n * a == pytest.approx((1.96 / 0.2) ** 2 * (1 - a), rel=0.01)

    def test_monotonicity(self):
        assert required_sample_size(0.001, 0.2) > required_sample_size(0.01, 0.2)
        assert required_sample_size(0.01, 0.1) > required_sample_size(0.01, 0.2)

    def test_inverse_roundtrip(self):
        a, n = 0.01, 100_000
        d = alert_rate_rel_error(a, n)
        assert required_sample_size(a, d) == pytest.approx(n, rel=0.01)

    def test_invalid_inputs(self):
        with pytest.raises(ValueError):
            required_sample_size(0.0, 0.1)
        with pytest.raises(ValueError):
            required_sample_size(0.5, 0.0)

    def test_empirical_coverage(self):
        """Monte-Carlo check of Appendix A: with n samples from Eq. 5, the
        realized alert rate deviates < delta·a from target ~95% of the time."""
        a, delta, z = 0.05, 0.25, 1.96
        n = required_sample_size(a, delta, z)
        rng = np.random.default_rng(0)
        hits = 0
        trials = 400
        for _ in range(trials):
            scores = rng.random(n)
            thr = np.quantile(scores, 1 - a)
            realized = np.mean(rng.random(200_000) > thr)
            if abs(realized - a) <= delta * a:
                hits += 1
        coverage = hits / trials
        assert coverage > 0.90, f"coverage {coverage} below nominal 95%"


class TestStreamingEstimator:
    def test_exact_below_capacity(self):
        rng = np.random.default_rng(1)
        data = rng.random(10_000)
        est = StreamingQuantileEstimator(capacity=16_384)
        est.update(data)
        q = est.quantiles(np.array([0.1, 0.5, 0.9]))
        np.testing.assert_allclose(q, np.quantile(data, [0.1, 0.5, 0.9]), atol=1e-12)

    def test_reservoir_unbiased_above_capacity(self):
        rng = np.random.default_rng(2)
        est = StreamingQuantileEstimator(capacity=8_192, seed=3)
        for _ in range(20):
            est.update(rng.beta(2, 5, 10_000))
        q = est.quantiles(np.array([0.25, 0.5, 0.75]))
        from scipy import stats
        true_q = stats.beta.ppf([0.25, 0.5, 0.75], 2, 5)
        np.testing.assert_allclose(q, true_q, atol=0.03)

    def test_ready_gating(self):
        est = StreamingQuantileEstimator(capacity=1024)
        assert not est.ready(alert_rate=0.01, rel_error=0.2)
        est.update(np.random.default_rng(0).random(required_sample_size(0.01, 0.2) + 1))
        assert est.ready(alert_rate=0.01, rel_error=0.2)

    def test_empty_raises(self):
        est = StreamingQuantileEstimator()
        with pytest.raises(ValueError):
            est.quantiles(np.array([0.5]))

    @given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_count_tracks_updates(self, n, seed):
        est = StreamingQuantileEstimator(capacity=256, seed=seed)
        est.update(np.random.default_rng(seed).random(n))
        assert est.count == n
        q = est.quantiles(np.array([0.0, 1.0]))
        assert q[0] <= q[1]


class TestBatchQuantiles:
    def test_monotone(self):
        rng = np.random.default_rng(4)
        levels, q = batch_quantiles(rng.random(1000), 65)
        assert (np.diff(q) >= 0).all()
        assert len(levels) == len(q) == 65


class TestMergeableSketches:
    """The fleet-calibration reduction: merge() must behave like a single
    estimator fed the concatenated stream, up to the documented rank-error
    bound (``merge_rank_error_bound``)."""

    LEVELS = np.linspace(0.02, 0.98, 25)

    @staticmethod
    def _rank_error(data: np.ndarray, est_q: np.ndarray,
                    levels: np.ndarray) -> float:
        ranks = np.searchsorted(np.sort(data), est_q, side="right") / len(data)
        return float(np.max(np.abs(ranks - levels)))

    @given(st.integers(2, 6), st.integers(0, 2**31 - 1),
           st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_random_splits_match_single_stream_within_bound(
            self, n_parts, seed, lognormal):
        """Split one stream randomly across n estimators, merge, and compare
        against the full stream: the merged sketch's quantile rank error
        must stay inside the documented two-stage subsampling bound."""
        rng = np.random.default_rng(seed)
        cap = 512
        n = 12_000
        data = rng.lognormal(0.0, 0.6, n) if lognormal \
            else rng.normal(0.0, 1.0, n)
        split = np.sort(rng.choice(np.arange(1, n), n_parts - 1,
                                   replace=False))
        parts = np.split(rng.permutation(data), split)
        ests = []
        for i, chunk in enumerate(parts):
            e = StreamingQuantileEstimator(capacity=cap, seed=seed + i,
                                           recent_capacity=64)
            if len(chunk):
                e.update(chunk)
            ests.append(e)
        merged = StreamingQuantileEstimator.merged(ests)
        assert merged.count == n
        err = self._rank_error(data, merged.quantiles(self.LEVELS),
                               self.LEVELS)
        # two uniform-subsampling stages of size >= cap (per-part reservoirs,
        # then the merge reselection); bound documented in core/quantiles.py
        bound = merge_rank_error_bound(cap, cap)
        assert err <= bound, (err, bound)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_acceptance_counts_associative_and_commutative(self, seed):
        """count/seen/retained-size are exactly invariant under merge order
        and grouping (the sampled VALUES may differ — the reduction is
        randomized — but the acceptance accounting may not)."""
        rng = np.random.default_rng(seed)
        cap = 128
        ests = []
        for i in range(4):
            e = StreamingQuantileEstimator(capacity=cap, seed=seed + 7 * i,
                                           recent_capacity=32)
            e.update(rng.normal(i, 1.0, int(rng.integers(10, 900))))
            ests.append(e)
        a, b, c, d = ests
        total = sum(e.count for e in ests)

        def stats(m):
            return (m.count, len(m.values()), m.capacity, m.recent_capacity)

        flat = StreamingQuantileEstimator.merged(ests)
        rev = StreamingQuantileEstimator.merged(ests[::-1])
        left = StreamingQuantileEstimator.merged(
            [StreamingQuantileEstimator.merged([a, b]), c, d])
        right = StreamingQuantileEstimator.merged(
            [a, StreamingQuantileEstimator.merged([b, c, d])])
        assert stats(flat) == stats(rev) == stats(left) == stats(right)
        assert flat.count == total
        assert len(flat.values()) == min(total, cap)

    def test_merge_preserves_exact_union_below_capacity(self):
        """While the union of retained samples fits, merge is LOSSLESS."""
        a = StreamingQuantileEstimator(capacity=1024, seed=1)
        b = StreamingQuantileEstimator(capacity=1024, seed=2)
        xa, xb = np.arange(100.0), np.arange(100.0, 250.0)
        a.update(xa)
        b.update(xb)
        m = a.merge(b)
        assert m.count == 250
        np.testing.assert_array_equal(np.sort(m.values()), np.arange(250.0))

    def test_checkpoint_roundtrip_after_merge_is_exact(self):
        """A merged estimator checkpoints/restores bit-exactly AND the
        restored copy evolves identically under further updates."""
        rng = np.random.default_rng(3)
        ests = []
        for i in range(3):
            e = StreamingQuantileEstimator(capacity=256, seed=i,
                                           recent_capacity=32)
            e.update(rng.normal(0, 1, 700))
            ests.append(e)
        m = StreamingQuantileEstimator.merged(ests)
        r = StreamingQuantileEstimator.from_checkpoint(
            m.checkpoint_arrays(), m.checkpoint_meta())
        np.testing.assert_array_equal(m.values(), r.values())
        np.testing.assert_array_equal(m.recent(), r.recent())
        assert m.count == r.count
        extra = rng.normal(0, 1, 500)
        m.update(extra)
        r.update(extra)
        np.testing.assert_array_equal(m.values(), r.values())
        np.testing.assert_array_equal(m.recent(), r.recent())

    def test_merge_checkpoints_equals_merge_of_estimators(self):
        """The wire-format reduction (merge_checkpoints) is the same
        operation as merging the live estimators."""
        rng = np.random.default_rng(9)
        ests = []
        for i in range(3):
            e = StreamingQuantileEstimator(capacity=128, seed=100 + i)
            e.update(rng.normal(0, 1, 400))
            ests.append(e)
        via_ckpt = StreamingQuantileEstimator.merge_checkpoints(
            [(e.checkpoint_arrays(), e.checkpoint_meta()) for e in ests])
        direct = StreamingQuantileEstimator.merged(ests)
        np.testing.assert_array_equal(np.sort(via_ckpt.values()),
                                      np.sort(direct.values()))
        assert via_ckpt.count == direct.count

    def test_merged_estimator_keeps_streaming(self):
        """Post-merge updates behave like a normal estimator: count grows,
        reservoir stays at capacity, recent ring tracks the newest tail."""
        ests = []
        rng = np.random.default_rng(11)
        for i in range(2):
            e = StreamingQuantileEstimator(capacity=64, seed=i,
                                           recent_capacity=16)
            e.update(rng.normal(0, 1, 200))
            ests.append(e)
        m = StreamingQuantileEstimator.merged(ests)
        m.update(np.full(16, 42.0))
        assert m.count == 416
        assert len(m.values()) == 64
        np.testing.assert_array_equal(m.recent(), np.full(16, 42.0))

    def test_bound_shrinks_with_stage_size(self):
        assert merge_rank_error_bound(4096) < merge_rank_error_bound(256)
        assert merge_rank_error_bound(256, 256) \
            == pytest.approx(2 * merge_rank_error_bound(256))
        assert merge_rank_error_bound() == 0.0


class TestUpdateChunkBound:
    """Regression for the update() chunk split: ``len // 65536`` (floor)
    allowed chunks up to 131071 — double the documented 65536 bound.
    Ceil division caps every chunk at the bound for all lengths."""

    @pytest.mark.parametrize("n", [0, 1, 65535, 65536, 65537,
                                   131071, 131072, 131073])
    def test_chunks_respect_documented_bound(self, n):
        est = StreamingQuantileEstimator(capacity=128, seed=0,
                                         recent_capacity=16)
        seen = []
        orig = est._update_chunk

        def spy(chunk):
            seen.append(len(chunk))
            return orig(chunk)

        est._update_chunk = spy
        est.update(np.zeros(n))
        assert sum(seen) == n
        assert all(c <= 65536 for c in seen)
        # no empty chunks except the degenerate n=0 call
        if n:
            assert all(c > 0 for c in seen)
        assert est.count == n

    def test_split_preserves_sample_order(self):
        """Boundary case straddling the old bug (one 131071-sample call):
        the reservoir fill phase must still see samples in arrival order."""
        est = StreamingQuantileEstimator(capacity=131072, seed=0)
        data = np.arange(131071, dtype=np.float64)
        est.update(data)
        assert np.array_equal(est.values(), data)
