"""Tests for the paper's Sec.-5 roadmap items implemented here:
closed-loop calibration refresh (drift monitoring) and generalized
posterior correction / weight adaptation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptation import (
    fit_aggregation_weights,
    generalized_correction_betas,
)
from repro.core.metrics import brier_score
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import posterior_correction
from repro.experiments.fraud_world import DIM, FraudWorld
from repro.serving.drift import (
    CalibrationRefreshController,
    DriftMonitor,
    psi,
    reference_bin_masses,
)
from repro.serving.server import MuseServer, ServerConfig
from repro.serving.types import ScoringRequest


class TestPSI:
    def test_identical_zero(self):
        p = np.array([0.2, 0.3, 0.5])
        assert psi(p, p) < 1e-9

    def test_shifted_large(self):
        assert psi(np.array([0.9, 0.1]), np.array([0.1, 0.9])) > 1.0

    def test_reference_bin_masses_sum_to_one(self):
        tq = np.linspace(0, 1, 64) ** 2
        masses = reference_bin_masses(tq, np.linspace(0, 1, 11))
        assert masses.sum() == pytest.approx(1.0, abs=1e-6)


class TestDriftMonitor:
    def test_aligned_stream_no_alarm(self):
        rng = np.random.default_rng(0)
        tq = np.quantile(rng.beta(2, 6, 100_000), np.linspace(0, 1, 128))
        mon = DriftMonitor(tq, window=8000)
        mon.update(rng.beta(2, 6, 8000))
        assert mon.current_psi() < 0.05
        assert not mon.drifted()

    def test_shifted_stream_alarms(self):
        rng = np.random.default_rng(1)
        tq = np.quantile(rng.beta(2, 6, 100_000), np.linspace(0, 1, 128))
        mon = DriftMonitor(tq, window=8000)
        mon.update(rng.beta(6, 2, 8000))   # strongly shifted
        assert mon.drifted()

    def test_insufficient_data_silent(self):
        mon = DriftMonitor(np.linspace(0, 1, 64), window=8000)
        mon.update(np.full(50, 0.99))
        assert not mon.drifted()


class TestClosedLoopRefresh:
    def test_drift_triggers_refresh_and_restores_alignment(self):
        """End-to-end roadmap item 1: a client whose distribution the
        cold-start transform mismatches gets auto-refreshed once the Eq.-5
        gate opens, and the post-refresh PSI drops back under alarm."""
        world = FraudWorld.build(seed=21, client_shift=0.5)
        names = ("m1", "m2", "m3")
        qm0 = world.coldstart_quantile_map(names, n_trials=1)
        server = MuseServer(
            RoutingTable((ScoringRule(Condition(), "p"),), version="v1"),
            ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5),
        )
        server.deploy(world.predictor_spec("p", names, qm0),
                      world.model_factories())
        ctl = CalibrationRefreshController(server, world.ref_quantiles,
                                           psi_alarm=0.25, window=4000)
        ctl.attach()

        x, _ = world.client.sample(8000)
        for i in range(0, len(x), 500):
            server.score_batch([
                ScoringRequest(intent=Intent(tenant="bank1"),
                               features=f.astype(np.float32))
                for f in x[i : i + 500]
            ])
        pre_psi = ctl._monitors[("bank1", "p")].current_psi()
        refreshed = ctl.tick()
        assert refreshed, f"no refresh happened (psi={pre_psi:.3f})"
        tenant, pred, drift = refreshed[0]
        assert (tenant, pred) == ("bank1", "p")
        assert drift > 0.25

        # after the swap, fresh traffic should align with R
        x2, _ = world.client.sample(6000)
        for i in range(0, len(x2), 500):
            server.score_batch([
                ScoringRequest(intent=Intent(tenant="bank1"),
                               features=f.astype(np.float32))
                for f in x2[i : i + 500]
            ])
        post_psi = ctl._monitors[("bank1", "p")].current_psi()
        assert post_psi < 0.1, f"post-refresh PSI {post_psi:.3f} still high"
        assert ctl.tick() == []  # loop converged, no further refresh


class TestWeightAdaptation:
    def test_weights_favor_the_informative_expert(self):
        rng = np.random.default_rng(2)
        n = 40_000
        p_true = rng.beta(0.6, 5, n)
        y = (rng.random(n) < p_true).astype(np.float64)
        good = np.clip(p_true + rng.normal(0, 0.02, n), 0.001, 0.999)
        noise = rng.uniform(0, 1, n)
        w = fit_aggregation_weights(np.stack([good, noise], -1), y)
        assert w[0] > 0.85
        assert w.sum() == pytest.approx(1.0, abs=1e-5)

    def test_fitted_ensemble_beats_uniform(self):
        rng = np.random.default_rng(3)
        n = 60_000
        p = rng.beta(0.6, 5, n)
        y = (rng.random(n) < p).astype(np.float64)
        e1 = np.clip(p + rng.normal(0, 0.05, n), 1e-3, 1 - 1e-3)
        e2 = np.clip(p + rng.normal(0, 0.25, n), 1e-3, 1 - 1e-3)
        s = np.stack([e1, e2], -1)
        w = fit_aggregation_weights(s, y)
        assert brier_score(s @ w, y) < brier_score(s.mean(-1), y)


class TestGeneralizedCorrection:
    def test_recovers_true_beta_from_labels(self):
        rng = np.random.default_rng(4)
        n = 120_000
        p = rng.beta(0.5, 8, n)
        y = (rng.random(n) < p).astype(np.float64)
        betas_true = np.array([0.05, 0.3])
        raw = np.stack([p / (p + b * (1 - p)) for b in betas_true], -1)
        fitted = generalized_correction_betas(raw, y,
                                              nominal_betas=np.array([0.5, 0.5]))
        np.testing.assert_allclose(fitted, betas_true, rtol=0.25)
        # and the fitted correction calibrates better than none
        corr = np.asarray(posterior_correction(jnp.asarray(raw),
                                               jnp.asarray(fitted)))
        for i in range(2):
            assert brier_score(corr[:, i], y) < brier_score(raw[:, i], y)
