"""Tests for intent-based routing (paper Sec. 2.5, Fig. 2)."""
import pytest

from repro.core.routing import (
    Condition,
    Intent,
    NoMatchingRule,
    RoutingTable,
    ScoringRule,
    ShadowRule,
)


def fig2_table() -> RoutingTable:
    """The exact declarative config of the paper's Figure 2."""
    return RoutingTable.from_dict(
        {
            "routing": {
                "scoringRules": [
                    {
                        "description": "Custom DAG for bank1",
                        "condition": {"tenants": ["bank1"]},
                        "targetPredictorName": "bank1-predictor-v1",
                    },
                    {
                        "description": "Custom DAG for tenants in US or LATAM, using schema v1",
                        "condition": {
                            "geographies": ["NAMER", "LATAM"],
                            "schemas": ["fraud_v1"],
                        },
                        "targetPredictorName": "america-predictor-v1",
                    },
                    {
                        "description": "Default DAG for cold start clients",
                        "condition": {},
                        "targetPredictorName": "global-predictor-v3",
                    },
                ],
                "shadowRules": [
                    {
                        "description": "Evaluate predictor v2 in shadow mode for bank1",
                        "condition": {"tenants": ["bank1"]},
                        "targetPredictorNames": ["bank1-predictor-v2"],
                    }
                ],
            }
        },
        version="fig2",
    )


class TestFig2Semantics:
    def test_bank1_live_plus_shadow(self):
        res = fig2_table().resolve(Intent(tenant="bank1"))
        assert res.live == "bank1-predictor-v1"
        assert res.shadows == ("bank1-predictor-v2",)

    def test_geography_and_schema_conjunction(self):
        t = fig2_table()
        res = t.resolve(Intent(tenant="bankX", geography="NAMER", schema="fraud_v1"))
        assert res.live == "america-predictor-v1"
        # schema mismatch -> falls through to catch-all
        res2 = t.resolve(Intent(tenant="bankX", geography="NAMER", schema="fraud_v2"))
        assert res2.live == "global-predictor-v3"

    def test_catch_all_cold_start(self):
        res = fig2_table().resolve(Intent(tenant="brand-new-client"))
        assert res.live == "global-predictor-v3"
        assert res.shadows == ()

    def test_sequential_first_match_wins(self):
        # bank1 in NAMER with fraud_v1 still hits the bank1 rule (rule order).
        res = fig2_table().resolve(
            Intent(tenant="bank1", geography="NAMER", schema="fraud_v1")
        )
        assert res.live == "bank1-predictor-v1"


class TestRoutingMechanics:
    def test_no_match_raises(self):
        t = RoutingTable(
            scoring_rules=(
                ScoringRule(Condition(tenants=("a",)), "p-a"),
            )
        )
        with pytest.raises(NoMatchingRule):
            t.resolve(Intent(tenant="b"))

    def test_multiple_shadow_rules_all_fire(self):
        t = RoutingTable(
            scoring_rules=(ScoringRule(Condition(), "live-p"),),
            shadow_rules=(
                ShadowRule(Condition(), ("s1", "s2")),
                ShadowRule(Condition(tenants=("t",)), ("s3",)),
                ShadowRule(Condition(tenants=("other",)), ("s4",)),
            ),
        )
        res = t.resolve(Intent(tenant="t"))
        assert res.shadows == ("s1", "s2", "s3")

    def test_live_excluded_from_shadows(self):
        t = RoutingTable(
            scoring_rules=(ScoringRule(Condition(), "p"),),
            shadow_rules=(ShadowRule(Condition(), ("p", "q")),),
        )
        assert t.resolve(Intent(tenant="x")).shadows == ("q",)

    def test_extra_fields_condition(self):
        cond = Condition.from_dict({"channels": ["card"], "customField": ["v"]})
        assert cond.matches(Intent(tenant="t", channel="card", extra={"customField": "v"}))
        assert not cond.matches(Intent(tenant="t", channel="card"))

    def test_transparent_model_switching(self):
        """Promotion = routing-table value update; intents never change."""
        t = fig2_table()
        t2 = t.with_rule_update("bank1-predictor-v1", "bank1-predictor-v2", "fig2+promo")
        intent = Intent(tenant="bank1")
        assert t.resolve(intent).live == "bank1-predictor-v1"   # old table intact
        assert t2.resolve(intent).live == "bank1-predictor-v2"  # new table promoted
        assert t2.version == "fig2+promo"

    def test_referenced_predictors(self):
        names = fig2_table().referenced_predictors()
        assert set(names) == {
            "bank1-predictor-v1",
            "america-predictor-v1",
            "global-predictor-v3",
            "bank1-predictor-v2",
        }
