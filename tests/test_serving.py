"""Serving-runtime tests: server data plane, shadows, batching, rollout."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule, ShadowRule
from repro.core.transforms import QuantileMap
from repro.serving.batching import MicroBatcher
from repro.serving.rollout import Replica, ReplicaSet, RollingUpdate
from repro.serving.server import MuseServer, ServerConfig
from repro.serving.types import ScoringRequest
from repro.serving.warmup import warm_up

DIM = 8


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


def _qm(n=32):
    return QuantileMap.identity(n)


def _basic_server(extra_shadow: bool = False) -> MuseServer:
    rules = [ScoringRule(Condition(tenants=("bank1",)), "p-bank1"),
             ScoringRule(Condition(), "p-global")]
    shadows = [ShadowRule(Condition(tenants=("bank1",)), ("p-shadow",))] if extra_shadow else []
    server = MuseServer(RoutingTable(tuple(rules), tuple(shadows), version="v1"))
    factories = {
        "m1": lambda: _linear_model(1),
        "m2": lambda: _linear_model(2),
        "m3": lambda: _linear_model(3),
    }
    server.deploy(PredictorSpec("p-bank1", ("m1", "m2"), (0.2, 0.2),
                                (1.0, 1.0), _qm()), factories)
    server.deploy(PredictorSpec.single("p-global", "m1", _qm()), factories)
    if extra_shadow:
        server.deploy(PredictorSpec("p-shadow", ("m1", "m2", "m3"),
                                    (0.2, 0.2, 0.05), (1.0, 1.0, 1.0), _qm()),
                      factories)
    return server


def _req(tenant="bank1", seed=0):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


class TestServerDataPlane:
    def test_routing_to_tenant_predictor(self):
        server = _basic_server()
        resp = server.score(_req("bank1"))
        assert resp.predictor == "p-bank1"
        assert 0.0 <= resp.score <= 1.0
        assert len(resp.raw_scores) == 2
        resp2 = server.score(_req("other"))
        assert resp2.predictor == "p-global"

    def test_model_dedup_across_predictors(self):
        server = _basic_server(extra_shadow=True)
        # m1, m2, m3 deployed once each despite three predictors sharing them
        assert server.pool.provision_events == 3
        assert set(server.pool.names()) == {"m1", "m2", "m3"}

    def test_shadow_scoring_does_not_affect_response(self):
        s_with = _basic_server(extra_shadow=True)
        s_without = _basic_server(extra_shadow=False)
        req = _req("bank1", seed=7)
        r1 = s_with.score(req)
        r2 = s_without.score(req)
        assert r1.score == pytest.approx(r2.score, abs=1e-7)
        assert len(s_with.sink) == 1
        assert len(s_without.sink) == 0
        rec = s_with.sink.records("p-shadow")[0]
        assert rec.tenant == "bank1"
        assert len(rec.raw_scores) == 3

    def test_batch_grouping_multi_tenant(self):
        server = _basic_server()
        reqs = [_req("bank1", i) for i in range(3)] + [_req("t2", i) for i in range(2)]
        resps = server.score_batch(reqs)
        assert [r.predictor for r in resps] == ["p-bank1"] * 3 + ["p-global"] * 2
        assert [r.request_id for r in resps] == [q.request_id for q in reqs]

    def test_transformation_swap_without_model_touch(self):
        server = _basic_server()
        prov_before = server.pool.provision_events
        qs = jnp.linspace(0, 1, 32)
        server.swap_transformation("p-bank1", QuantileMap(qs, qs**2))
        assert server.pool.provision_events == prov_before  # zero models touched
        resp = server.score(_req("bank1"))
        assert 0.0 <= resp.score <= 1.0

    def test_banked_skip_stats_as_serving_metric(self):
        """skip_blocks_* metrics through the REAL dispatcher path: a window
        sorted by tenant is all uniform blocks (scalar-prefetch fast path),
        an interleaved window defeats it entirely."""
        rules = [ScoringRule(Condition(tenants=("bank1",)), "p-bank1"),
                 ScoringRule(Condition(), "p-bank2")]
        factories = {"m1": lambda: _linear_model(1),
                     "m2": lambda: _linear_model(2)}

        def mk():
            server = MuseServer(RoutingTable(tuple(rules), version="v1"))
            # two predictors sharing one model group -> one banked window
            server.deploy(PredictorSpec("p-bank1", ("m1", "m2"), (0.2, 0.2),
                                        (1.0, 1.0), _qm()), factories)
            server.deploy(PredictorSpec("p-bank2", ("m1", "m2"), (0.2, 0.2),
                                        (1.0, 1.0), _qm()), factories)
            return server

        n = 2048  # two kernel blocks of 1024
        sorted_reqs = [_req("bank1", i) for i in range(n // 2)] + \
            [_req("bank2", i) for i in range(n // 2)]
        server = mk()
        server.score_batch(sorted_reqs)
        assert server.metrics["skip_blocks_total"] == 2
        assert server.metrics["skip_blocks_uniform"] == 2  # skip rate 1.0

        interleaved = [r for pair in zip(sorted_reqs[: n // 2],
                                         sorted_reqs[n // 2:]) for r in pair]
        server = mk()
        server.score_batch(interleaved)
        assert server.metrics["skip_blocks_total"] == 2
        assert server.metrics["skip_blocks_uniform"] == 0  # skip rate 0.0

    def test_publish_routing_validates_targets(self):
        server = _basic_server()
        bad = RoutingTable((ScoringRule(Condition(), "ghost"),), version="v2")
        with pytest.raises(KeyError):
            server.publish_routing(bad)

    def test_feature_enrichment_for_wider_models(self):
        """Easy Feature Evolution: a model with a wider feature set gets its
        derived features from the store; clients keep sending DIM features."""
        server = _basic_server()
        wide_dim = DIM + 4
        server.deploy(
            PredictorSpec.single("p-wide", "m-wide", _qm()),
            {"m-wide": lambda: _linear_model(9, wide_dim)},
        )
        server.predictors["p-wide"]._handles[0].metadata["feature_dim"] = wide_dim
        server.features.put("bank1", np.full(4, 0.5))
        server.publish_routing(RoutingTable(
            (ScoringRule(Condition(tenants=("bank1",)), "p-wide"),
             ScoringRule(Condition(), "p-global")), version="v3"))
        resp = server.score(_req("bank1"))
        assert resp.predictor == "p-wide"
        assert 0.0 <= resp.score <= 1.0

    def test_shadow_dedup_reuses_raw_scores_within_model_group(self):
        """A shadow predictor sharing its request's live model group must NOT
        re-run the expert models: raw scores are cached per (group, request)
        inside score_batch, so the shadow costs one extra banked kernel
        dispatch but zero extra model executions."""
        rules = [ScoringRule(Condition(tenants=("bank1",)), "p-bank1"),
                 ScoringRule(Condition(), "p-global")]
        shadows = [ShadowRule(Condition(tenants=("bank1",)), ("p-shadow-same",))]
        server = MuseServer(RoutingTable(tuple(rules), tuple(shadows),
                                         version="v1"))
        factories = {"m1": lambda: _linear_model(1),
                     "m2": lambda: _linear_model(2)}
        server.deploy(PredictorSpec("p-bank1", ("m1", "m2"), (0.2, 0.2),
                                    (1.0, 1.0), _qm()), factories)
        server.deploy(PredictorSpec("p-shadow-same", ("m1", "m2"), (0.5, 0.8),
                                    (2.0, 1.0), _qm()), factories)
        server.deploy(PredictorSpec.single("p-global", "m1", _qm()), factories)
        reqs = [_req("bank1", seed=i) for i in range(4)]
        before = dict(server.metrics)
        resps = server.score_batch(reqs)
        # live + shadow each take a banked kernel dispatch...
        assert server.metrics["kernel_dispatches"] - before["kernel_dispatches"] == 2
        # ...but the {m1,m2} group executed exactly ONCE (2 model forwards)
        assert server.metrics["model_group_calls"] - before["model_group_calls"] == 1
        assert server.metrics["model_calls"] - before["model_calls"] == 2
        # shadow records reused the live dispatch's raw expert scores
        recs = server.sink.records("p-shadow-same")
        assert len(recs) == 4
        for resp, rec in zip(resps, recs):
            assert rec.raw_scores == resp.raw_scores
            assert rec.score != pytest.approx(resp.score, abs=1e-9)

    def test_shadow_distinct_model_group_still_runs_models(self):
        """Control case: a shadow on a DIFFERENT model group cannot reuse
        raw scores — it pays its own model execution."""
        server = _basic_server(extra_shadow=True)  # shadow adds m3
        before = dict(server.metrics)
        server.score_batch([_req("bank1", seed=3)])
        assert server.metrics["kernel_dispatches"] - before["kernel_dispatches"] == 2
        assert server.metrics["model_group_calls"] - before["model_group_calls"] == 2
        # live {m1,m2} = 2 forwards + shadow {m1,m2,m3} = 3 forwards
        assert server.metrics["model_calls"] - before["model_calls"] == 5

    def test_calibration_refresh_gate_and_fit(self):
        cfgd = ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5)
        server = _basic_server()
        server.config = cfgd
        assert not server.calibration_ready("bank1", "p-bank1")
        n_needed = 1 + int(1.96**2 * 0.95 / (0.25 * 0.05))
        for i in range(0, n_needed, 64):
            server.score_batch([_req("bank1", seed=i + j) for j in range(64)])
        assert server.calibration_ready("bank1", "p-bank1")
        qm = server.fit_custom_quantile_map("bank1", "p-bank1",
                                            np.linspace(0, 1, 64))
        assert (np.diff(np.asarray(qm.src_quantiles)) >= -1e-7).all()


class TestMicroBatcher:
    def test_size_trigger(self):
        mb = MicroBatcher(max_batch=3, max_wait_ms=1e9)
        assert mb.add("p", _req()) is None
        assert mb.add("p", _req()) is None
        batch = mb.add("p", _req())
        assert batch is not None and len(batch) == 3
        assert mb.pending_count == 0

    def test_age_trigger_with_fake_clock(self):
        t = [0.0]
        mb = MicroBatcher(max_batch=100, max_wait_ms=5.0, clock=lambda: t[0])
        mb.add("p", _req())
        assert mb.expired() == []
        t[0] = 0.006
        expired = mb.expired()
        assert len(expired) == 1 and len(expired[0][1]) == 1

    def test_keys_are_independent(self):
        mb = MicroBatcher(max_batch=2, max_wait_ms=1e9)
        mb.add("a", _req())
        assert mb.add("b", _req()) is None
        assert mb.add("a", _req()) is not None


class TestRollout:
    def test_rolling_update_availability_and_version_shift(self):
        def make_server(version="v1"):
            s = _basic_server()
            s.routing = RoutingTable(s.routing.scoring_rules,
                                     s.routing.shadow_rules, version=version)
            return s

        replicas = [Replica(i, make_server(), "v1", ready=True) for i in range(3)]
        rs = ReplicaSet(replicas)
        update = RollingUpdate(rs, lambda: make_server("v2"), "v2",
                               schema_dim=DIM, warmup_batch_sizes=(1, 4))

        def traffic():
            i = 0
            while True:
                yield [_req("bank1", seed=i), _req("t2", seed=i + 1)]
                i += 2

        timeline = update.run_with_traffic(traffic(), batches_per_transition=2)
        # availability: every sample had >= 3 ready replicas (maxUnavailable=0)
        assert min(t["ready_count"] for t in timeline) >= 3
        # surge: pod count peaked above baseline
        assert max(t["pod_count"] for t in timeline) == 4
        # traffic fully shifted to v2 by the end
        assert timeline[-1]["version"] == "v2"
        versions = {t["version"] for t in timeline}
        assert versions == {"v1", "v2"}
        # every replica was warmed before serving
        assert all(r.warmup_seconds > 0 for r in rs.replicas)

    def test_warmup_compiles_all_predictors(self):
        server = _basic_server(extra_shadow=True)
        timings = warm_up(server, DIM, batch_sizes=(1, 2))
        assert set(timings) == {"p-bank1", "p-global", "p-shadow"}
        # warmed path: subsequent call is fast and doesn't recompile
        import time
        t0 = time.perf_counter()
        server.score_batch([_req("bank1", seed=1)])
        assert time.perf_counter() - t0 < 0.5
