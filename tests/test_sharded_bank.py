"""Multi-device parity campaign: tenant-sharded transform banks.

Proves the ROADMAP's "Sharded transform banks" item: row-partitioning the
``TransformBank`` over a mesh "tenants" axis (each replica shard holds only
its tenant rows) changes WHERE the parameters live but not a single bit of
WHAT gets served.  The campaign asserts, on 1/2/4/8 host devices:

  * sharded-vs-dense score parity is EXACT (bitwise on f32) — the per-shard
    banked kernel runs the identical per-row fp op sequence as the dense
    dispatch, whatever the assignment;
  * the partition machinery is lossless under arbitrary tenant->shard
    permutations, uneven occupancy, empty shards, and tenants absent from a
    batch (hypothesis-shim property sweep);
  * ``refresh_fleet`` publishes land atomically ACROSS shards: a traffic
    thread never observes a torn per-shard mix and the fleet generation
    stays monotone (concurrency case).

The estimator-persistence tests ride along unmarked (no devices needed):
a surged replica restores its (tenant, predictor) reservoirs and starts
past the Eq.-5 gate instead of cold.

Marked ``sharded`` -> ``./test.sh --sharded`` (which exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); shard counts
beyond the available device count skip at runtime so a plain single-device
pytest pass stays green.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import (
    QuantileMap,
    ShardedTransformBank,
    TransformBank,
    banked_score_pipeline,
    score_pipeline,
)
from repro.kernels import ops
from repro.launch.mesh import make_tenant_mesh
from repro.serving import (
    AsyncDispatchEngine,
    CalibrationController,
    MuseServer,
    RefreshPolicy,
    ServerConfig,
    ShardedBankDispatcher,
)
from repro.serving.types import ScoringRequest

NDEV = jax.device_count()
TOL = 1e-5
DIM = 8
SHARD_COUNTS = (1, 2, 4, 8)


def _needs_devices(n: int) -> None:
    if NDEV < n:
        pytest.skip(f"needs {n} devices, have {NDEV} "
                    "(run via ./test.sh --sharded)")


def _bits(x) -> np.ndarray:
    return np.asarray(x, np.float32).view(np.uint32)


def _random_bank(rng, t, k, n, generation=0) -> TransformBank:
    betas = rng.uniform(0.05, 1.0, (t, k)).astype(np.float32)
    weights = rng.uniform(0.1, 2.0, (t, k)).astype(np.float32)
    src = np.sort(rng.uniform(0.0, 1.0, (t, n)), axis=-1).astype(np.float32)
    ref = np.sort(rng.uniform(0.0, 1.0, (t, n)), axis=-1).astype(np.float32)
    return TransformBank(
        betas=jnp.asarray(betas), weights=jnp.asarray(weights),
        src_quantiles=jnp.asarray(src), ref_quantiles=jnp.asarray(ref),
        generation=generation)


def _dense_scores(bank, scores, tid) -> np.ndarray:
    return np.asarray(ops.score_pipeline_banked(
        jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
        bank.src_quantiles, bank.ref_quantiles))


# ---------------------------------------------------------------------------
# Partition machinery (pure array plumbing — no mesh required)
# ---------------------------------------------------------------------------

@pytest.mark.sharded
class TestShardedBankStructure:
    def test_round_trip_is_lossless(self):
        rng = np.random.default_rng(0)
        bank = _random_bank(rng, 13, 3, 32, generation=7)
        sbank = ShardedTransformBank.from_dense(bank, 4)
        assert sbank.num_shards == 4
        assert sbank.num_rows == 13
        assert sbank.generation == 7
        assert int(sbank.row_counts.sum()) == 13
        back = sbank.to_dense()
        for field in ("betas", "weights", "src_quantiles", "ref_quantiles"):
            np.testing.assert_array_equal(
                np.asarray(getattr(back, field)),
                np.asarray(getattr(bank, field)))
        assert back.generation == 7
        # the remap is a bijection rows -> (shard, local slot)
        pairs = set(zip(sbank.shard_of.tolist(), sbank.local_of.tolist()))
        assert len(pairs) == 13
        assert all(0 <= l < sbank.rows_per_shard for _, l in pairs)

    def test_uneven_occupancy_and_empty_shards(self):
        rng = np.random.default_rng(1)
        bank = _random_bank(rng, 6, 2, 16)
        # everything piles onto shard 2 of 4: shards 0/1/3 are EMPTY
        assign = np.full(6, 2)
        sbank = ShardedTransformBank.from_dense(bank, 4, shard_of=assign)
        np.testing.assert_array_equal(sbank.row_counts, [0, 0, 6, 0])
        assert sbank.rows_per_shard == 6
        back = sbank.to_dense()
        np.testing.assert_array_equal(np.asarray(back.betas),
                                      np.asarray(bank.betas))
        # an empty shard still exposes a well-formed (inert) sub-bank
        assert sbank.shard_bank(0).num_rows == 1
        assert sbank.shard_bank(2).num_rows == 6

    def test_per_shard_bytes_shrink_with_shard_count(self):
        rng = np.random.default_rng(2)
        bank = _random_bank(rng, 64, 4, 256)
        dense_bytes = 64 * (2 * 4 + 2 * 256) * 4
        for s in (1, 2, 4, 8):
            sbank = ShardedTransformBank.from_dense(bank, s)
            assert sbank.per_shard_bytes * s == pytest.approx(
                dense_bytes, rel=0.05)

    def test_with_rows_scatters_only_into_owning_shard(self):
        rng = np.random.default_rng(3)
        bank = _random_bank(rng, 8, 2, 16)
        sbank = ShardedTransformBank.from_dense(bank, 4)  # round-robin t % 4
        qm = QuantileMap(jnp.linspace(0, 1, 16), jnp.linspace(0, 1, 16) ** 2)
        out = sbank.with_rows({5: qm})                    # owner: shard 1
        owner = int(sbank.shard_of[5])
        assert owner == 1
        for s in range(4):
            same_src = np.array_equal(_bits(out.src_quantiles[s]),
                                      _bits(sbank.src_quantiles[s]))
            assert same_src == (s != owner)
        # the receiver is untouched; the update landed at (owner, local)
        local = int(sbank.local_of[5])
        np.testing.assert_array_equal(
            np.asarray(out.src_quantiles[owner, local]),
            np.asarray(qm.src_quantiles))
        assert out.generation == sbank.generation + 1
        # narrow tables edge-pad, wide tables are a shape error (dense parity)
        narrow = QuantileMap(jnp.linspace(0, 1, 8), jnp.linspace(0, 1, 8))
        padded = sbank.with_rows({0: narrow})
        assert padded.num_quantiles == 16
        wide = QuantileMap(jnp.linspace(0, 1, 64), jnp.linspace(0, 1, 64))
        with pytest.raises(ValueError):
            sbank.with_rows({0: wide})

    def test_with_rows_matches_dense_with_rows(self):
        """Sharded and dense functional updates stay interchangeable."""
        rng = np.random.default_rng(4)
        bank = _random_bank(rng, 10, 3, 32)
        sbank = ShardedTransformBank.from_dense(bank, 4)
        updates = {2: QuantileMap(jnp.linspace(0, 1, 32),
                                  jnp.linspace(0, 1, 32) ** 3),
                   7: QuantileMap(jnp.linspace(0, 1, 32),
                                  jnp.sqrt(jnp.linspace(0, 1, 32)))}
        dense_new = bank.with_rows(updates, generation=5)
        sharded_new = sbank.with_rows(updates, generation=5).to_dense()
        for field in ("betas", "weights", "src_quantiles", "ref_quantiles"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sharded_new, field)),
                np.asarray(getattr(dense_new, field)))
        assert sharded_new.generation == dense_new.generation == 5

    def test_bad_assignment_raises(self):
        bank = _random_bank(np.random.default_rng(5), 4, 2, 8)
        with pytest.raises(ValueError):
            ShardedTransformBank.from_dense(bank, 0)
        with pytest.raises(ValueError):
            ShardedTransformBank.from_dense(bank, 2, shard_of=np.array([0, 1]))
        with pytest.raises(ValueError):
            ShardedTransformBank.from_dense(
                bank, 2, shard_of=np.array([0, 1, 2, 0]))
        with pytest.raises(IndexError):
            ShardedTransformBank.from_dense(bank, 2).with_rows(
                {9: QuantileMap.identity(8)})


# ---------------------------------------------------------------------------
# Sharded-vs-dense parity on real host devices
# ---------------------------------------------------------------------------

@pytest.mark.sharded
class TestShardedDispatchParity:
    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    def test_bitwise_parity_vs_dense_kernel(self, shards):
        _needs_devices(shards)
        rng = np.random.default_rng(100 + shards)
        t, k, n, b = 23, 3, 64, 517
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b)
        dense = _dense_scores(bank, scores, tid.astype(np.int32))
        sbank = ShardedTransformBank.from_dense(bank, shards)
        disp = ShardedBankDispatcher(make_tenant_mesh(shards))
        got = disp(scores, tid, sbank)
        assert np.array_equal(_bits(got), _bits(dense))

    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    def test_unfused_fallback_parity(self, shards):
        _needs_devices(shards)
        rng = np.random.default_rng(200 + shards)
        t, k, n, b = 11, 2, 32, 260
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b)
        dense = np.asarray(banked_score_pipeline(
            jnp.asarray(scores), jnp.asarray(tid.astype(np.int32)),
            bank.betas, bank.weights, bank.src_quantiles,
            bank.ref_quantiles))
        sbank = ShardedTransformBank.from_dense(bank, shards)
        disp = ShardedBankDispatcher(make_tenant_mesh(shards), fused=False)
        got = disp(scores, tid, sbank)
        np.testing.assert_allclose(got, dense, atol=TOL, rtol=TOL)


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2, 3)}


def _req(tenant, seed):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


def _fleet(n_tenants=6, *, shards=1) -> MuseServer:
    """One predictor per tenant, all sharing one model group, so a mixed
    batch is ONE multi-tenant banked window."""
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version="v1"),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5,
                     tenant_shards=shards))
    rng = np.random.default_rng(42)
    for i in range(n_tenants):
        n = 32
        qm = QuantileMap(
            src_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, n)),
                                      jnp.float32),
            ref_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, n)),
                                      jnp.float32))
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"),
                                    (0.2 + 0.1 * (i % 3), 0.4),
                                    (1.0, 1.0 + i % 2), qm), FACTORIES)
    return server


@pytest.mark.sharded
class TestShardedServerParity:
    @pytest.mark.parametrize("shards", SHARD_COUNTS)
    def test_score_batch_bitwise_vs_dense_server(self, shards):
        _needs_devices(shards)
        dense, sharded = _fleet(6), _fleet(6, shards=shards)
        reqs = [_req(f"t{i % 6}", 1000 + i) for i in range(37)]
        want = dense.score_batch(reqs)
        got = sharded.score_batch(reqs)
        assert [r.request_id for r in got] == [r.request_id for r in want]
        for a, b in zip(got, want):
            assert a.score == b.score, (a.predictor, a.score, b.score)
            assert a.bank_generation == b.bank_generation
        # the whole mixed window went through the sharded dispatch path
        # (tenant_shards=1 IS the dense path by design — no mesh to split)
        if shards > 1:
            assert sharded.metrics["shard_dispatches"] == \
                sharded.metrics["kernel_dispatches"] == 1
        else:
            assert sharded.metrics["shard_dispatches"] == 0
        assert dense.metrics["shard_dispatches"] == 0

    def test_engine_serves_through_sharded_path(self):
        _needs_devices(4)
        dense, sharded = _fleet(4), _fleet(4, shards=4)
        reqs = [_req(f"t{i % 4}", 2000 + i) for i in range(32)]
        want = {r.request_id: r.score for r in dense.score_batch(reqs)}
        engine = AsyncDispatchEngine(sharded, max_batch=8, max_wait_ms=1e9)
        out = engine.score_batch(reqs)
        engine.close()
        assert sharded.metrics["shard_dispatches"] >= 1
        for r in out:
            assert r.score == want[r.request_id]


# ---------------------------------------------------------------------------
# Property sweep: permutations, uneven occupancy, absent tenants
# ---------------------------------------------------------------------------

@pytest.mark.sharded
class TestShardedProperties:
    @settings(max_examples=10)
    @given(st.integers(0, 10_000), st.integers(1, 8), st.integers(1, 31))
    def test_arbitrary_assignment_preserves_scores_bitwise(
            self, seed, shards, t):
        """Any tenant->shard permutation — uneven, with empty shards, with
        tenants absent from the batch — serves bitwise-identical scores."""
        if NDEV < shards:
            return  # drawn shard count beyond this host's devices
        rng = np.random.default_rng(seed)
        k, n, b = 2, 16, 97
        bank = _random_bank(rng, t, k, n)
        # arbitrary assignment: uneven occupancy, shards may be empty
        assign = rng.integers(0, shards, t)
        sbank = ShardedTransformBank.from_dense(bank, shards, shard_of=assign)
        # batch over a SUBSET of tenants (some tenants absent entirely)
        present = rng.choice(t, size=max(1, t // 2), replace=False)
        tid = rng.choice(present, size=b)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        dense = _dense_scores(bank, scores, tid.astype(np.int32))
        disp = ShardedBankDispatcher(make_tenant_mesh(shards))
        got = disp(scores, tid, sbank)
        assert np.array_equal(_bits(got), _bits(dense))
        # and the partition itself is lossless
        np.testing.assert_array_equal(
            np.asarray(sbank.to_dense().src_quantiles),
            np.asarray(bank.src_quantiles))

    @settings(max_examples=8)
    @given(st.integers(0, 10_000), st.integers(2, 8))
    def test_permuted_assignment_equals_default(self, seed, shards):
        """The assignment is representation only: two different layouts of
        the same bank score every request identically (bitwise)."""
        if NDEV < shards:
            return
        rng = np.random.default_rng(seed)
        t, k, n, b = 12, 3, 32, 130
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b)
        disp = ShardedBankDispatcher(make_tenant_mesh(shards))
        default = disp(scores, tid,
                       ShardedTransformBank.from_dense(bank, shards))
        permuted = disp(scores, tid, ShardedTransformBank.from_dense(
            bank, shards, shard_of=rng.permutation(t) % shards))
        assert np.array_equal(_bits(default), _bits(permuted))


# ---------------------------------------------------------------------------
# Atomic cross-shard calibration publish under live concurrency
# ---------------------------------------------------------------------------

def _inject(server, tenant, pred, n=5000, seed=0):
    rng = np.random.default_rng(seed)
    est = StreamingQuantileEstimator(capacity=131072, seed=seed)
    est.update(rng.uniform(0, 1, n))
    server._estimators[(tenant, pred)] = est
    return est


def _pipeline_registry(server):
    return {n: p.pipeline for n, p in server.predictors.items()}


@pytest.mark.sharded
@pytest.mark.concurrency
class TestShardedRefreshAtomicity:
    """``refresh_fleet`` publishes must land atomically ACROSS shards: the
    dense bank and every per-shard sub-bank swap in one control-plane
    assignment, so a traffic thread can never see shard A at generation g
    and shard B at g+1, and the fleet generation is monotone."""

    def test_publishes_are_atomic_across_shards(self):
        _needs_devices(4)
        n_t = 8
        server = _fleet(n_t, shards=4)
        server.score_batch([_req(f"t{i % n_t}", 30_000 + i)
                            for i in range(16)])  # compile before the clock
        for i in range(n_t):
            _inject(server, f"t{i}", f"p{i}", seed=i)
        ref = np.linspace(0.0, 1.0, 64) ** 2
        ctrl = CalibrationController(
            server, ref,
            RefreshPolicy(alert_rate=0.05, rel_error=0.5, n_levels=64))
        registry = {server.bank_generation: _pipeline_registry(server)}
        res0 = ctrl.refresh_fleet()     # warm the refresh path pre-clock
        assert res0.generation == 1
        registry[1] = _pipeline_registry(server)

        engine = AsyncDispatchEngine(server, max_batch=16, max_wait_ms=1e9,
                                     facade_timeout_s=300.0)
        reqs = [_req(f"t{i % n_t}", i) for i in range(960)]
        stop = threading.Event()
        published: list[int] = []

        def writer():
            while not stop.is_set() and len(published) < 40:
                res = ctrl.refresh_fleet()
                registry[res.generation] = _pipeline_registry(server)
                published.append(res.generation)

        wt = threading.Thread(target=writer)
        tt = threading.Thread(target=lambda: [engine.submit(r) for r in reqs])
        wt.start()
        tt.start()
        tt.join(timeout=300.0)
        assert not tt.is_alive(), "traffic thread wedged"
        responses = engine.drain(timeout=300.0)
        stop.set()
        wt.join(timeout=300.0)
        assert not wt.is_alive(), "refresh writer wedged"
        engine.close()

        # 1:1 delivery, and publishes really overlapped the traffic
        assert sorted(r.request_id for r in responses) == \
            sorted(r.request_id for r in reqs)
        assert len(published) >= 2
        # ONE fleet generation per publish: strictly consecutive, no skips
        # (a torn per-shard publish would surface as a duplicated or
        # out-of-order generation)
        assert published == list(range(2, 2 + len(published)))
        # every response reproduces from the pipelines of the ONE generation
        # it is stamped with — any cross-shard tear diverges
        for resp in responses:
            pipe = registry[resp.bank_generation][resp.predictor]
            want = float(score_pipeline(
                jnp.asarray(resp.raw_scores, jnp.float32), pipe.betas,
                pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
            assert resp.score == pytest.approx(want, abs=TOL), \
                (resp.request_id, resp.predictor, resp.bank_generation)
        # per-stream generations never step back
        seen: dict[str, int] = {}
        for resp in responses:
            last = seen.get(resp.predictor, -1)
            assert resp.bank_generation >= last
            seen[resp.predictor] = resp.bank_generation


# ---------------------------------------------------------------------------
# Estimator persistence (warm surge) — runs in the fast lane, no devices
# ---------------------------------------------------------------------------

class TestEstimatorPersistence:
    def test_estimator_round_trip_is_exact(self, tmp_path):
        rng = np.random.default_rng(0)
        est = StreamingQuantileEstimator(capacity=256, seed=3,
                                         recent_capacity=32)
        est.update(rng.uniform(0, 1, 700))   # past capacity: reservoir live
        restored = StreamingQuantileEstimator.from_checkpoint(
            est.checkpoint_arrays(), est.checkpoint_meta())
        assert restored.count == est.count
        np.testing.assert_array_equal(restored.values(), est.values())
        np.testing.assert_array_equal(restored.recent(), est.recent())
        levels = np.linspace(0, 1, 33)
        np.testing.assert_array_equal(restored.quantiles(levels),
                                      est.quantiles(levels))
        # the RNG state round-trips too: both continue the SAME
        # reservoir-acceptance sequence
        more = rng.uniform(0, 1, 500)
        est.update(more)
        restored.update(more)
        np.testing.assert_array_equal(restored.values(), est.values())

    def test_surged_replica_restores_past_eq5_gate(self, tmp_path):
        """save -> restore -> the Eq.-5 gate still passes and a refresh
        ships — the warm-surge lifecycle."""
        alert_rate, rel_error = 0.05, 0.5
        need = required_sample_size(alert_rate, rel_error)
        server = _fleet(3)
        rng = np.random.default_rng(9)
        for i in range(3):
            est = StreamingQuantileEstimator(capacity=8192, seed=i)
            est.update(rng.uniform(0, 1, need + 50))
            server._estimators[(f"t{i}", f"p{i}")] = est
        assert server.calibration_ready("t0", "p0")
        path = server.save_estimators(str(tmp_path / "est"), step=4)
        assert path.endswith("4")

        surged = _fleet(3)                 # fresh replica: cold streams
        assert not surged.calibration_ready("t0", "p0")
        n = surged.restore_estimators(str(tmp_path / "est"))  # latest step
        assert n == 3
        for i in range(3):
            assert surged.calibration_ready(f"t{i}", f"p{i}")
            np.testing.assert_array_equal(
                surged._estimators[(f"t{i}", f"p{i}")].values(),
                server._estimators[(f"t{i}", f"p{i}")].values())
        # the restored streams refit + validate + publish like live ones
        ref = np.linspace(0.0, 1.0, 64) ** 2
        ctrl = CalibrationController(
            surged, ref,
            RefreshPolicy(alert_rate=alert_rate, rel_error=rel_error,
                          n_levels=64))
        res = ctrl.refresh_fleet()
        assert len(res.refreshed) == 3, [r.reasons for r in res.reports]
        assert surged.bank_generation == 1

    def test_restore_missing_checkpoint_raises(self, tmp_path):
        server = _fleet(2)
        with pytest.raises(FileNotFoundError):
            server.restore_estimators(str(tmp_path / "nope"))
