"""Tiered-over-sharded composition: per-shard hot tiers on the tenant mesh.

PR 9 lifts the tiering/`tenant_shards` mutual exclusion: every shard of
the tenant mesh owns a bounded hot tier + victim cache + pinned prior row
over its slice of the host store (``ShardedTieredBankStore``), and one
``shard_map`` launch per pass scores all shards' slot-remapped buckets
through the same fused banked kernel.  The campaign asserts, on 1/2/4/8
host devices:

  * composed scores match the dense bank AND the pure-sharded dispatcher
    BITWISE on f32 — cold path, warm path, multi-pass victim overflow,
    after prefetch and after rebalance;
  * device residency is ``(hot+victims+1)·(2K+2N)·4`` bytes PER SHARD,
    constant across tenant counts (host bytes grow; device bytes do not);
  * the fenced-publish contract survives composition: one
    ``apply_updates`` lands in every shard's host rows and device view
    under ONE generation, per-shard generations advance in lockstep,
    stale stamps are rejected, and a bad update touches no shard
    (property-tested over random dispatch/promote/publish/mark-cold
    schedules);
  * the serving layer composes end to end: ``ServerConfig(tenant_shards,
    tiering)`` server parity, engine-pipelined parity, and cross-topology
    ``warm_tiers_from`` (single-tier victim -> composed surge and back).

S=1 cases run on a plain single-device pytest pass; S>1 cases skip
unless the device count allows (``./test.sh --tiering`` exports the
8-virtual-device XLA_FLAGS).  Campaign classes are marked ``tiering``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap, ShardedTransformBank, shard_rows
from repro.kernels import ops
from repro.launch.mesh import make_tenant_mesh
from repro.serving import (
    AsyncDispatchEngine,
    MuseServer,
    ServerConfig,
    ShardedBankDispatcher,
    StaleGenerationError,
)
from repro.serving.tiering import (
    HostBankStore,
    ShardedTieredBankStore,
    TieringConfig,
)
from test_tiering import (
    _TIER_CFG,
    _req,
    _tenant_server,
    EASY_GATE,
    FACTORIES,
)

NDEV = jax.device_count()
SHARD_COUNTS = (1, 2, 4, 8)


def _needs_devices(n: int) -> None:
    if NDEV < n:
        pytest.skip(f"needs {n} devices, have {NDEV} "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _bitwise(a: np.ndarray, b: np.ndarray) -> bool:
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return bool(np.array_equal(a.view(np.uint32), b.view(np.uint32)))


def _mono(rng, t, n) -> np.ndarray:
    q = np.cumsum(rng.uniform(1e-3, 1.0, (t, n)).astype(np.float32),
                  axis=1, dtype=np.float32)
    return q / q[:, -1:]


def _host(rng, t, k=4, n=32) -> HostBankStore:
    return HostBankStore(
        rng.uniform(0.05, 1.0, (t, k)).astype(np.float32),
        rng.uniform(0.1, 2.0, (t, k)).astype(np.float32),
        _mono(rng, t, n), _mono(rng, t, n))


def _cfg(hot=4, victims=2, **kw) -> TieringConfig:
    return TieringConfig(hot_capacity=hot, victim_capacity=victims,
                         **{**EASY_GATE, **kw})


def _dense_scores(host: HostBankStore, raws, tid) -> np.ndarray:
    bank = host.dense_bank(0)
    return np.asarray(ops.score_pipeline_banked(
        jnp.asarray(raws), jnp.asarray(tid, jnp.int32), bank.betas,
        bank.weights, bank.src_quantiles, bank.ref_quantiles))


# --------------------------------------------------------------------------
# store-level bitwise parity (dense + pure-sharded oracles)
# --------------------------------------------------------------------------

class TestComposedParity:
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_bitwise_parity_vs_dense_and_pure_sharded(self, s):
        _needs_devices(s)
        rng = np.random.default_rng(100 + s)
        t, k = 37, 4
        host = _host(rng, t, k=k)
        mesh = make_tenant_mesh(s)
        dispatcher = ShardedBankDispatcher(mesh)
        store = ShardedTieredBankStore(host, s, _cfg(),
                                       dispatcher=dispatcher)
        sharded = ShardedTransformBank.from_dense(host.dense_bank(0), s)
        raws = rng.uniform(0, 1, (48, k)).astype(np.float32)
        tid = rng.integers(0, t, 48)
        want = _dense_scores(host, raws, tid)
        # pure-sharded oracle through the SAME dispatcher
        pure = dispatcher(raws, np.asarray(tid, np.int32), sharded)
        assert _bitwise(pure, want)
        # cold path: every row pages through victim caches (multi-pass —
        # 37 tenants over at most 6 resident slots per shard)
        got, gen = store.dispatch(raws, tid)
        assert _bitwise(got, want)
        assert gen == 0
        assert store.metrics["cold_miss_stalls"] > 0
        # warm path: residents serve straight from the device views
        got2, _ = store.dispatch(raws, tid)
        assert _bitwise(got2, want)
        # prefetch + rebalance do not perturb served values
        store.prefetch(tid)
        store.rebalance()
        got3, _ = store.dispatch(raws, tid)
        assert _bitwise(got3, want)

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_multipass_overflow_parity(self, s):
        _needs_devices(s)
        rng = np.random.default_rng(200 + s)
        t, k = 64, 4
        host = _host(rng, t, k=k)
        # victim_capacity=1: every shard pages one row per pass, so a
        # window spanning all tenants forces many joint passes
        store = ShardedTieredBankStore(host, s, _cfg(hot=2, victims=1))
        tid = np.arange(t)
        raws = rng.uniform(0, 1, (t, k)).astype(np.float32)
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, _dense_scores(host, raws, tid))
        assert store.metrics["extra_passes"] > 0

    def test_row_partition_matches_sharded_bank_rule(self):
        # the composed store and ShardedTransformBank must bucket a tenant
        # to the SAME shard, or engine prefetch and rebalance would warm
        # the wrong shard's tier
        assign, local, counts = shard_rows(11, 4)
        assert np.array_equal(assign, np.arange(11) % 4)
        host = _host(np.random.default_rng(3), 11)
        store = ShardedTieredBankStore(host, 4, _cfg(),
                                       dispatcher=object())
        assert np.array_equal(store.shard_of, assign)
        assert np.array_equal(store.local_of, local)
        assert np.array_equal(store.row_counts, counts)


# --------------------------------------------------------------------------
# per-shard residency bound
# --------------------------------------------------------------------------

class TestComposedResidency:
    def test_per_shard_device_bytes_independent_of_tenants(self):
        rng = np.random.default_rng(7)
        k, n, hot, victims = 4, 32, 4, 2
        per_shard = []
        for t in (16, 64, 256):
            store = ShardedTieredBankStore(
                _host(rng, t, k=k, n=n), 1, _cfg(hot=hot, victims=victims))
            per_shard.append(store.per_shard_device_bytes)
        assert len(set(per_shard)) == 1
        assert per_shard[0] == (hot + victims + 1) * (2 * k + 2 * n) * 4

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_device_bytes_scale_with_shards_not_tenants(self, s):
        _needs_devices(s)
        rng = np.random.default_rng(8)
        store = ShardedTieredBankStore(_host(rng, 61), s, _cfg())
        assert store.device_bytes == s * store.per_shard_device_bytes
        assert store.host_bytes == _host(rng, 61).nbytes

    def test_uneven_shards_share_hot_slot_count(self):
        # 5 rows over 4 shards: shard 0 owns 2 rows, shard 3 owns 1 —
        # every shard still gets the same hot-slot count so the per-shard
        # views stack into one (S, R, ·) shard_map operand
        store = ShardedTieredBankStore(
            _host(np.random.default_rng(9), 5), 4, _cfg(hot=8),
            dispatcher=object())
        assert len({st.hot_capacity for st in store.shards}) == 1
        assert len({st.device_bytes for st in store.shards}) == 1


# --------------------------------------------------------------------------
# fenced publish across shards
# --------------------------------------------------------------------------

class TestComposedPublish:
    def _store(self, s=2, t=13, seed=11):
        rng = np.random.default_rng(seed)
        host = _host(rng, t)
        return ShardedTieredBankStore(host, s, _cfg()), host, rng

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_publish_lands_on_every_shard_under_one_generation(self, s):
        _needs_devices(s)
        store, host, rng = self._store(s=s)
        t = host.num_rows
        raws = rng.uniform(0, 1, (t, 4)).astype(np.float32)
        tid = np.arange(t)
        store.dispatch(raws, tid)              # make rows device-resident
        n = host.num_quantiles
        upd = {row: QuantileMap(np.sort(rng.uniform(0, 1, n)),
                                np.linspace(0, 1, n) ** 2)
               for row in range(0, t, 3)}      # rows spanning every shard
        gen = store.apply_updates(upd)
        assert gen == 1
        assert all(st.generation == 1 for st in store.shards)
        # the oracle host store takes the same updates -> bitwise parity
        # proves hot AND victim device copies were rescattered everywhere
        host.write_rows(upd)
        got, got_gen = store.dispatch(raws, tid)
        assert got_gen == 1
        assert _bitwise(got, _dense_scores(host, raws, tid))

    def test_fenced_fast_forward_and_stale_rejection(self):
        store, _, _ = self._store(s=1)
        assert store.apply_updates({}, generation=5) == 5
        assert store.generation == 5
        assert all(st.generation == 5 for st in store.shards)
        with pytest.raises(StaleGenerationError):
            store.apply_updates({}, generation=5)
        with pytest.raises(StaleGenerationError):
            store.rebalance(generation=4)      # rebalance fenced the other way
        assert store.rebalance(generation=5)["generation"] == 5
        assert store.generation == 5           # rebalance never bumps

    def test_bad_update_touches_no_shard(self):
        store, host, rng = self._store(s=1, t=6)
        n = host.num_quantiles
        good = QuantileMap(np.sort(rng.uniform(0, 1, n)),
                           np.linspace(0, 1, n) ** 2)
        wide = QuantileMap(np.sort(rng.uniform(0, 1, 2 * n)),
                           np.linspace(0, 1, 2 * n))
        before = [st.host.src_quantiles.copy() for st in store.shards]
        with pytest.raises(ValueError):
            store.apply_updates({0: good, 5: wide})
        assert store.generation == 0
        for st, b in zip(store.shards, before):
            assert np.array_equal(st.host.src_quantiles, b)
        with pytest.raises(IndexError):
            store.apply_updates({99: good})


# --------------------------------------------------------------------------
# property sweep: random op schedules keep shards lockstep + bitwise
# --------------------------------------------------------------------------

@pytest.mark.tiering
class TestComposedScheduleProperty:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1))
    def test_random_schedule_lockstep_generations_and_parity(self, seed):
        if NDEV < 2:
            pytest.skip("needs 2 devices")
        rng = np.random.default_rng(seed)
        t = int(rng.integers(5, 24))
        host = _host(rng, t)
        oracle = HostBankStore(host.betas, host.weights,
                               host.src_quantiles, host.ref_quantiles)
        store = ShardedTieredBankStore(host, 2, _cfg(hot=3, victims=2))
        n = host.num_quantiles
        for _ in range(12):
            op = rng.choice(["dispatch", "prefetch", "rebalance",
                             "publish", "fenced", "mark_cold"])
            if op == "dispatch":
                b = int(rng.integers(1, 17))
                tid = rng.integers(0, t, b)
                raws = rng.uniform(0, 1, (b, 4)).astype(np.float32)
                got, gen = store.dispatch(raws, tid)
                # score only admitted rows against the oracle (cold-marked
                # rows serve the prior; their parity is pinned elsewhere)
                adm = np.zeros(t, bool)
                for s, sub in enumerate(store.shards):
                    adm[store.global_of[s]] = sub.host.admitted
                mask = adm[tid]
                want = _dense_scores(oracle, raws, tid)
                assert _bitwise(got[mask], want[mask])
                assert gen == store.generation
            elif op == "prefetch":
                store.prefetch(rng.integers(0, t, 8))
            elif op == "rebalance":
                store.rebalance()
            elif op == "publish":
                rows = rng.choice(t, rng.integers(1, 4), replace=False)
                upd = {int(r): QuantileMap(np.sort(rng.uniform(0, 1, n)),
                                           np.linspace(0, 1, n) ** 2)
                       for r in rows}
                store.apply_updates(upd)
                oracle.write_rows(upd)
            elif op == "fenced":
                store.apply_updates({}, generation=store.generation + 3)
            elif op == "mark_cold":
                row = int(rng.integers(0, t))
                store.mark_cold([row])
            gens = {st.generation for st in store.shards}
            assert gens == {store.generation}, "shard generations diverged"


# --------------------------------------------------------------------------
# serving layer: server + engine + rollout warm start
# --------------------------------------------------------------------------

def _composed_server(n_tenants=4, shards=2,
                     tiering=_TIER_CFG) -> MuseServer:
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version="v1"),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5,
                     tenant_shards=shards, tiering=tiering))
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


@pytest.mark.tiering
class TestComposedServing:
    def test_server_parity_and_store_type(self):
        _needs_devices(2)
        comp = _composed_server()
        dense = _tenant_server(4)
        reqs = [_req(f"t{i % 4}", seed=i) for i in range(16)]
        rd = dense.score_batch(list(reqs))
        rc = comp.score_batch(list(reqs))
        for a, b in zip(rd, rc):
            assert a.score == b.score
            assert a.bank_generation == b.bank_generation == 0
        (store,) = comp.tiered_stores().values()
        assert isinstance(store, ShardedTieredBankStore)
        assert store.num_shards == 2
        assert comp.metrics["tier_dispatches"] >= 1
        assert comp.metrics["shard_dispatches"] >= 1

    def test_server_publish_parity_and_stamp(self):
        _needs_devices(2)
        rng = np.random.default_rng(21)
        comp = _composed_server()
        dense = _tenant_server(4)
        reqs = [_req(f"t{i % 4}", seed=i) for i in range(8)]
        comp.score_batch(list(reqs))
        dense.score_batch(list(reqs))
        qm = QuantileMap(np.sort(rng.uniform(0, 1, 64)),
                         np.linspace(0.0, 1.0, 64) ** 2)
        assert dense.publish_quantile_maps({"p1": qm, "p2": qm}) == 1
        assert comp.publish_quantile_maps({"p1": qm, "p2": qm}) == 1
        rd = dense.score_batch(list(reqs))
        rc = comp.score_batch(list(reqs))
        for a, b in zip(rd, rc):
            assert a.score == b.score
            assert b.bank_generation == 1

    def test_engine_pipeline_parity(self):
        _needs_devices(2)
        comp = _composed_server()
        dense = _tenant_server(4)
        engine = AsyncDispatchEngine(comp, max_batch=6, max_wait_ms=1e9)
        try:
            futs = [engine.submit(_req(f"t{i % 4}", seed=i))
                    for i in range(24)]
            engine.flush()
            scores = [f.result(timeout=60).score for f in futs]
            assert not engine.errors
        finally:
            engine.close()
        want = [r.score for r in dense.score_batch(
            [_req(f"t{i % 4}", seed=i) for i in range(24)])]
        assert scores == want
        assert comp.metrics["shard_dispatches"] >= 1

    def test_engine_prefetch_routes_to_composed_store(self):
        _needs_devices(2)
        # 8 predictors over 2 shards: 4 rows per shard, hot=3 + victims=2
        # slots — a full window leaves cold rows for prefetch to stage
        comp = _composed_server(n_tenants=8)
        comp.score_batch([_req(f"t{i}", i) for i in range(8)])
        assert comp.prefetch_enabled
        names = [f"p{i}" for i in range(8)]
        staged = comp.prefetch_transforms(names, create=False)
        assert staged >= 1
        (store,) = comp.tiered_stores().values()
        assert store.metrics["prefetched_rows"] >= staged

    def test_warm_tiers_across_topologies(self):
        _needs_devices(2)
        single = _tenant_server(4, tiering=_TIER_CFG)
        # one window over all four predictors keys the ("p0".."p3") store,
        # with traffic concentrated on rows 1 and 2 (store rows are
        # group-local: row i serves predictor p<i>)
        reqs = [_req("t1", seed=i) for i in range(3)] + \
            [_req("t2", seed=i + 100) for i in range(3)] + \
            [_req("t0", seed=200), _req("t3", seed=201)]
        single.score_batch(list(reqs))
        single.rebalance_tiers()
        (old_store,) = single.tiered_stores().values()
        assert {1, 2} <= set(old_store.hot_rows().tolist())
        # surge a composed replica from the single-tier victim: the
        # global-indexed snapshot scatters hotness onto the owning shards
        comp = _composed_server()
        assert comp.warm_tiers_from(single) == 1
        (store,) = comp.tiered_stores().values()
        assert isinstance(store, ShardedTieredBankStore)
        assert {1, 2} <= set(store.hot_rows().tolist())
        # ... and back: a single-tier replica warms from the composed one
        single2 = _tenant_server(4, tiering=_TIER_CFG)
        assert single2.warm_tiers_from(comp) == 1
        (s2,) = single2.tiered_stores().values()
        assert {1, 2} <= set(s2.hot_rows().tolist())
