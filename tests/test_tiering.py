"""Tiered tenant-bank store: hot device rows, host-paged cold rows, priors.

Covers the three-tier serving contract end to end:

  * **parity** — a tiered dispatch (slot-remapped rows through the same
    fused banked kernel) matches a dense ``TransformBank`` built from the
    same rows BITWISE on f32, in the hot-path steady state, across cold
    misses, multi-pass windows, and promotions;
  * **cold start** — a tenant with no history scores through the fitted
    Beta-mixture default quantiles (Eqs. 6–8) until its stream passes the
    Eq.-5 sample-size gate, then is admitted and (once hot) promoted;
  * **atomic publish** — ``apply_updates`` lands refreshed maps in host
    rows AND every device-resident copy under ONE generation; a
    post-publish read of any tenant — hot, cold, or freshly promoted —
    serves the new generation's parameters (property-tested over random
    promote/demote/publish/mark-cold schedules under the ``tiering``
    marker);
  * **integration** — the single-server and fleet calibration refresh
    paths, the async engine's anti-stall prefetch, and rollout warm-up
    (a surged replica adopting its victim's hot set).

The fast unit subset rides the default (tier-1) lane unmarked; the
campaign classes are ``-m tiering`` (``./test.sh --tiering``).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hotness import HotnessTracker
from repro.core.predictor import PredictorSpec
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap, TransformBank, banked_score_pipeline
from repro.kernels import ops
from repro.serving import (
    CalibrationController,
    FleetCalibrationController,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    RollingUpdate,
    ServerConfig,
    StaleGenerationError,
)
from repro.serving.engine import AsyncDispatchEngine
from repro.serving.tiering import (
    HostBankStore,
    TieredBankStore,
    TieringConfig,
    prior_bank_row,
)
from repro.serving.types import ScoringRequest

DIM = 8


# --------------------------------------------------------------------------
# shared builders
# --------------------------------------------------------------------------

def _random_bank(rng, t, k=4, n=32) -> TransformBank:
    return TransformBank(
        betas=jnp.asarray(rng.uniform(0.05, 1.0, (t, k)), jnp.float32),
        weights=jnp.asarray(rng.uniform(0.1, 2.0, (t, k)), jnp.float32),
        src_quantiles=jnp.asarray(
            np.sort(rng.uniform(0, 1, (t, n)), -1), jnp.float32),
        ref_quantiles=jnp.asarray(
            np.sort(rng.uniform(0, 1, (t, n)), -1), jnp.float32))


def _dense_scores(bank: TransformBank, raws, tid, fused=True) -> np.ndarray:
    impl = ops.score_pipeline_banked if fused else banked_score_pipeline
    return np.asarray(impl(
        jnp.asarray(raws, jnp.float32), jnp.asarray(tid, jnp.int32),
        bank.betas, bank.weights, bank.src_quantiles, bank.ref_quantiles))


def _bitwise(a: np.ndarray, b: np.ndarray) -> bool:
    return np.array_equal(np.asarray(a, np.float32).view(np.uint32),
                          np.asarray(b, np.float32).view(np.uint32))


# an "easy" Eq.-5 gate: required_sample_size(0.5, 1.0) == 4 events
EASY_GATE = dict(gate_alert_rate=0.5, gate_rel_error=1.0)


def _store(rng, t=32, hot=8, victims=4, **kw) -> tuple[TieredBankStore,
                                                       TransformBank]:
    bank = _random_bank(rng, t)
    cfg = TieringConfig(hot_capacity=hot, victim_capacity=victims,
                        **{**EASY_GATE, **kw})
    return TieredBankStore(HostBankStore.from_bank(bank), cfg), bank


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2)}
REF64 = np.linspace(0.0, 1.0, 64) ** 2


def _tenant_server(n_tenants=4, tiering: TieringConfig | None = None,
                   version="v1") -> MuseServer:
    """One predictor per tenant over a shared {m1, m2} model group."""
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version=version),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5,
                     tiering=tiering))
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


def _req(tenant, seed):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


def _inject(server, tenant, pred, samples, seed=0):
    est = StreamingQuantileEstimator(capacity=65536, seed=seed)
    est.update(samples)
    server._estimators[(tenant, pred)] = est
    return est


def _policy(**kw) -> RefreshPolicy:
    base = dict(alert_rate=0.05, rel_error=0.5, n_levels=64)
    base.update(kw)
    return RefreshPolicy(**base)


_TIER_CFG = TieringConfig(hot_capacity=3, victim_capacity=2, **EASY_GATE)


# --------------------------------------------------------------------------
# hotness tracker (core/hotness.py)
# --------------------------------------------------------------------------

class TestHotnessTracker:
    def test_decay_orders_recent_over_stale(self):
        tr = HotnessTracker(4, decay=0.5)
        tr.record(np.array([0, 0, 0, 0]))     # old burst on key 0
        tr.tick(3)                            # three quiet windows
        tr.record(np.array([1, 1]))           # fresh traffic on key 1
        assert tr.score(1) > tr.score(0)
        assert list(tr.top(2)) == [1, 0]

    def test_lazy_decay_matches_closed_form(self):
        tr = HotnessTracker(2, decay=0.9)
        expect = 0.0
        for w in range(50):
            tr.record(np.array([0]))
            expect = expect * 0.9 + 0.0  # decay applies on tick below
            tr.tick()
        # score = sum_{w=0..49} 0.9^(50-w) applied per-tick after each record
        want = sum(0.9 ** (50 - w) for w in range(50))
        assert tr.score(0) == pytest.approx(want, rel=1e-12)
        assert tr.windows == 50

    def test_rescale_keeps_scores_exact(self):
        tr = HotnessTracker(2, decay=0.5)
        tr.record(np.array([0]))
        tr.tick(400)                          # 0.5^400 << rescale floor
        tr.record(np.array([1]))
        assert tr.score(1) == pytest.approx(1.0, rel=1e-9)
        assert tr.score(0) == pytest.approx(0.0, abs=1e-100)

    def test_top_respects_mask_and_zero_scores(self):
        tr = HotnessTracker(4, decay=1.0)
        tr.record(np.array([0, 0, 1, 2]))
        mask = np.array([False, True, True, True])
        assert list(tr.top(3, mask=mask)) == [1, 2]   # 0 masked, 3 never seen
        assert list(tr.top(0)) == []

    def test_snapshot_adopt_roundtrip_and_resize(self):
        tr = HotnessTracker(3, decay=0.9)
        tr.record(np.array([0, 1, 1]))
        tr.tick()
        snap = tr.snapshot()
        other = HotnessTracker(5, decay=0.9)
        other.adopt(snap)
        assert other.score(1) == pytest.approx(tr.score(1))
        smaller = HotnessTracker(2, decay=0.9)
        smaller.adopt(snap)                    # common prefix only
        assert smaller.score(0) == pytest.approx(tr.score(0))

    def test_invalid_decay_rejected(self):
        with pytest.raises(ValueError):
            HotnessTracker(2, decay=0.0)
        with pytest.raises(ValueError):
            HotnessTracker(2, decay=1.5)


# --------------------------------------------------------------------------
# host store (authoritative numpy rows)
# --------------------------------------------------------------------------

class TestHostBankStore:
    def test_from_rows_matches_dense_bank_padding(self):
        rng = np.random.default_rng(0)
        params = [
            (rng.uniform(0.1, 1, 2), rng.uniform(0.5, 2, 2),
             np.sort(rng.uniform(0, 1, 16)), np.sort(rng.uniform(0, 1, 16))),
            (rng.uniform(0.1, 1, 3), rng.uniform(0.5, 2, 3),
             np.sort(rng.uniform(0, 1, 8)), np.sort(rng.uniform(0, 1, 8))),
        ]
        host = HostBankStore.from_rows(params)
        bank = TransformBank.from_params(params)
        assert _bitwise(host.betas, np.asarray(bank.betas))
        assert _bitwise(host.src_quantiles, np.asarray(bank.src_quantiles))
        assert host.num_rows == 2 and host.num_experts == 3
        assert host.nbytes == host.betas.nbytes * 2 + \
            host.src_quantiles.nbytes * 2

    def test_write_rows_pads_like_with_rows(self):
        rng = np.random.default_rng(1)
        bank = _random_bank(rng, 4, n=32)
        host = HostBankStore.from_bank(bank)
        qm = QuantileMap(np.sort(rng.uniform(0, 1, 16)),
                         np.sort(rng.uniform(0, 1, 16)))
        host.write_rows({2: qm})
        updated = bank.with_rows({2: qm})
        assert _bitwise(host.src_quantiles, np.asarray(updated.src_quantiles))
        assert _bitwise(host.ref_quantiles, np.asarray(updated.ref_quantiles))

    def test_write_rows_rejects_bad_rows_and_wide_tables(self):
        rng = np.random.default_rng(2)
        host = HostBankStore.from_bank(_random_bank(rng, 4, n=16))
        with pytest.raises(IndexError):
            host.write_rows({9: QuantileMap.identity(16)})
        with pytest.raises(ValueError):
            host.write_rows({0: QuantileMap.identity(64)})

    def test_mismatched_row_counts_rejected(self):
        with pytest.raises(ValueError):
            HostBankStore(np.ones((3, 2)), np.ones((2, 2)),
                          np.ones((3, 8)), np.ones((3, 8)))


# --------------------------------------------------------------------------
# tiered store: parity + staging
# --------------------------------------------------------------------------

class TestTieredDispatchParity:
    def test_bitwise_parity_cold_and_warm(self):
        rng = np.random.default_rng(3)
        store, bank = _store(rng, t=32, hot=8, victims=4)
        raws = rng.uniform(0, 1, (64, 4)).astype(np.float32)
        tid = rng.integers(0, 32, 64)
        want = _dense_scores(bank, raws, tid)
        got, gen = store.dispatch(raws, tid)          # all-miss first window
        assert _bitwise(got, want)
        assert gen == 0
        store.rebalance()                             # promote the hot set
        got2, _ = store.dispatch(raws, tid)           # warm path
        assert _bitwise(got2, want)
        assert store.metrics["hot_hits"] > 0

    def test_oracle_kernel_parity(self):
        rng = np.random.default_rng(4)
        store, bank = _store(rng, t=16, hot=4, victims=2, fused_kernel=False)
        raws = rng.uniform(0, 1, (16, 4)).astype(np.float32)
        tid = rng.integers(0, 16, 16)
        got, _ = store.dispatch(raws, tid)
        want = np.asarray(banked_score_pipeline(
            jnp.asarray(raws), jnp.asarray(tid, jnp.int32), bank.betas,
            bank.weights, bank.src_quantiles, bank.ref_quantiles))
        assert _bitwise(got, want)

    def test_device_bytes_bounded_by_config_not_tenants(self):
        rng = np.random.default_rng(5)
        small, _ = _store(rng, t=32, hot=8, victims=4)
        large, _ = _store(rng, t=2048, hot=8, victims=4)
        assert small.device_bytes == large.device_bytes
        assert large.host_bytes > 16 * small.device_bytes
        # exact bound: (hot + victim + prior row) * (2K+2N) * 4
        assert large.device_bytes == (8 + 4 + 1) * (2 * 4 + 2 * 32) * 4

    def test_window_wider_than_victim_cache_multi_pass(self):
        rng = np.random.default_rng(6)
        store, bank = _store(rng, t=32, hot=2, victims=2)
        raws = rng.uniform(0, 1, (24, 4)).astype(np.float32)
        tid = np.arange(24) % 12                      # 12 distinct cold rows
        want = _dense_scores(bank, raws, tid)
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, want)
        assert store.metrics["extra_passes"] > 0      # capacity < working set

    def test_prefetch_removes_cold_miss_stalls(self):
        rng = np.random.default_rng(7)
        store, bank = _store(rng, t=32, hot=8, victims=4)
        tid = np.array([3, 9, 3, 17])
        staged = store.prefetch(tid)
        assert staged == 3                            # distinct cold rows
        raws = rng.uniform(0, 1, (4, 4)).astype(np.float32)
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, _dense_scores(bank, raws, tid))
        assert store.metrics["cold_miss_stalls"] == 0
        assert store.metrics["victim_hits"] == 4
        assert store.prefetch(tid) == 0               # already resident

    def test_promotion_moves_hot_tenants_to_hot_slots(self):
        rng = np.random.default_rng(8)
        store, bank = _store(rng, t=32, hot=4, victims=2)
        hot_traffic = np.repeat(np.array([5, 6, 7, 8]), 8)
        raws = rng.uniform(0, 1, (len(hot_traffic), 4)).astype(np.float32)
        store.dispatch(raws, hot_traffic)
        res = store.rebalance()
        assert res["promoted"] == 4
        assert set(store.hot_rows()) == {5, 6, 7, 8}
        store.dispatch(raws, hot_traffic)
        assert store.metrics["hot_hits"] >= len(hot_traffic)
        # shifted traffic demotes the stale hot set after enough windows
        new_traffic = np.repeat(np.array([1, 2, 3, 4]), 8)
        for _ in range(40):
            store.dispatch(raws, new_traffic)
            store.rebalance()
        assert set(store.hot_rows()) == {1, 2, 3, 4}
        assert store.metrics["demotions"] >= 4

    def test_empty_window_is_noop(self):
        rng = np.random.default_rng(9)
        store, _ = _store(rng, t=8)
        out, gen = store.dispatch(np.empty((0, 4), np.float32),
                                  np.empty(0, np.int64))
        assert out.shape == (0,) and gen == 0
        assert store.metrics["dispatches"] == 0

    def test_dispatch_hot_path_has_no_tenant_linear_alloc(self):
        """PR 9 regression: per-window seen counting used
        ``self._seen += np.bincount(tid, minlength=T)`` — an O(T) int64
        temp (8 MB at 10^6 tenants) per window, under the dispatch lock.
        The ``np.add.at`` scatter is O(window); pin the hot-path peak
        well below one O(T) temp."""
        import tracemalloc
        t, k, n = 1_000_000, 1, 2
        host = HostBankStore(
            np.ones((t, k), np.float32), np.ones((t, k), np.float32),
            np.broadcast_to(np.array([0.0, 1.0], np.float32), (t, n)).copy(),
            np.broadcast_to(np.array([0.0, 1.0], np.float32), (t, n)).copy())
        store = TieredBankStore(
            host, TieringConfig(hot_capacity=4, victim_capacity=2,
                                **EASY_GATE))
        rng = np.random.default_rng(10)
        tid = rng.integers(0, 2, 64)
        raws = rng.uniform(0, 1, (64, k)).astype(np.float32)
        store.dispatch(raws, tid)          # warm: stage rows + compile
        tracemalloc.start()
        store.dispatch(raws, tid)          # pure device-hit window
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert store.metrics["cold_miss_stalls"] == 2  # warm-up only
        # the old bincount temp alone was t * 8 = 8_000_000 bytes
        assert peak < 4_000_000, f"O(T) allocation on the hot path: {peak}"

    def test_multipass_pad_slot_eviction_parity(self):
        """_score_slots edge-pads a bucketed slot vector with the LAST
        event's slot, which may be a live victim slot.  Pad references
        must not protect that slot from eviction in later passes of the
        SAME window (protection is rebuilt per pass from the unpadded
        event slots) — force exactly that eviction and require bitwise
        parity."""
        rng = np.random.default_rng(11)
        store, bank = _store(rng, t=8, hot=1, victims=2)
        seen_calls: list[tuple[np.ndarray, np.ndarray]] = []
        orig = store._score_slots

        def spy(raws, slots, view):
            seen_calls.append((np.asarray(slots).copy(),
                               store._owner.copy()))
            return orig(raws, slots, view)

        store._score_slots = spy
        # pass 1 stages rows {0, 1} and scores events [0, 0, 1] — length
        # 3, padded to 4 with row 1's victim slot; pass 2 must evict BOTH
        # victim slots (rows 2, 3 stage over them), re-owning the slot
        # pass 1's pad referenced
        tid = np.array([0, 0, 1, 2, 3])
        raws = rng.uniform(0, 1, (5, 4)).astype(np.float32)
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, _dense_scores(bank, raws, tid))
        assert store.metrics["extra_passes"] >= 1
        assert len(seen_calls) >= 2
        slots0, _ = seen_calls[0]
        assert len(slots0) == 3                 # padded call (bucket = 4)
        pad_slot = int(slots0[-1])
        assert store.hot_capacity <= pad_slot < store.hot_capacity + 2
        owners = [own[pad_slot] for _, own in seen_calls]
        assert any(o != owners[0] for o in owners[1:]), \
            "pad-referenced victim slot was never evicted in a later pass"


# --------------------------------------------------------------------------
# overlapped (double-buffered) prefetch staging — the PR 9 stall fix
# --------------------------------------------------------------------------

class TestOverlappedStaging:
    def test_dispatch_proceeds_while_prefetch_copy_in_flight(self,
                                                             monkeypatch):
        """The host->device victim copy runs OFF the dispatch lock: a
        dispatch completes while a prefetch's staged-view build is stuck
        mid-copy (with the lock held across the copy this deadlocks)."""
        import threading
        rng = np.random.default_rng(12)
        store, bank = _store(rng, t=16, hot=2, victims=4)
        store.prefetch(np.array([1, 2]))       # make rows 1, 2 resident
        orig = TieredBankStore._staged_view
        started, release = threading.Event(), threading.Event()

        def slow(self, view, slots, take):
            started.set()
            assert release.wait(timeout=30)
            return orig(self, view, slots, take)

        monkeypatch.setattr(TieredBankStore, "_staged_view", slow)
        result: dict = {}
        th = threading.Thread(
            target=lambda: result.update(n=store.prefetch(np.array([5, 6]))))
        th.start()
        try:
            assert started.wait(timeout=30)
            # copy in flight, lock free: this dispatch (pure victim-hit,
            # no staging of its own) must complete NOW, not after release
            tid = np.array([1, 2, 1])
            raws = rng.uniform(0, 1, (3, 4)).astype(np.float32)
            got, _ = store.dispatch(raws, tid)
            assert _bitwise(got, _dense_scores(bank, raws, tid))
        finally:
            release.set()
            th.join(timeout=30)
        assert result["n"] == 2                # commit landed after release
        assert store.metrics["staging_conflicts"] == 0
        assert {5, 6} <= set(store.resident_rows())

    def test_conflicting_publish_invalidates_staged_view(self, monkeypatch):
        """A publish landing while the prefetch copy is in flight swaps
        the view; the commit's identity check must catch it (conflict),
        restage under the lock, and serve the NEW generation's rows."""
        rng = np.random.default_rng(13)
        store, bank = _store(rng, t=16, hot=2, victims=4)
        qm = QuantileMap(np.sort(rng.uniform(0, 1, 32)),
                         np.linspace(0.0, 1.0, 32) ** 2)
        orig = TieredBankStore._staged_view
        fired: list[int] = []

        def hostile(self, view, slots, take):
            if not fired:
                fired.append(1)
                # runs with NO lock held (that is the point of the
                # overlap) — a concurrent publish swaps the view
                store.apply_updates({5: qm})
            return orig(self, view, slots, take)

        monkeypatch.setattr(TieredBankStore, "_staged_view", hostile)
        assert store.prefetch(np.array([5, 6])) == 2   # restaged path
        assert store.metrics["staging_conflicts"] == 1
        assert store.generation == 1
        # the restaged rows carry the POST-publish host values
        tid = np.array([5, 6])
        raws = rng.uniform(0, 1, (2, 4)).astype(np.float32)
        got, gen = store.dispatch(raws, tid)
        assert gen == 1
        assert store.metrics["cold_miss_stalls"] == 0
        want_bank = store.host.dense_bank(1)
        assert _bitwise(got, _dense_scores(want_bank, raws, tid))

    def test_mark_cold_during_copy_vetoes_commit(self, monkeypatch):
        """mark_cold flips admission WITHOUT swapping the view — the
        commit must re-check eligibility, not just view identity, or a
        cold-marked tenant's stale row lands device-resident."""
        rng = np.random.default_rng(14)
        store, _ = _store(rng, t=16, hot=2, victims=4)
        orig = TieredBankStore._staged_view
        fired: list[int] = []

        def hostile(self, view, slots, take):
            if not fired:
                fired.append(1)
                store.mark_cold([5])
            return orig(self, view, slots, take)

        monkeypatch.setattr(TieredBankStore, "_staged_view", hostile)
        assert store.prefetch(np.array([5])) == 0
        assert store.metrics["staging_conflicts"] == 1
        assert 5 not in set(store.resident_rows())

    def test_legacy_locked_staging_still_correct(self):
        """overlap_staging=False keeps the old hold-the-lock-across-the-
        copy behavior (the bench's before/after baseline)."""
        rng = np.random.default_rng(15)
        store, bank = _store(rng, t=16, hot=2, victims=4,
                             overlap_staging=False)
        assert store.prefetch(np.array([3, 4, 5])) == 3
        tid = np.array([3, 4, 5, 3])
        raws = rng.uniform(0, 1, (4, 4)).astype(np.float32)
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, _dense_scores(bank, raws, tid))
        assert store.metrics["cold_miss_stalls"] == 0
        assert store.metrics["staging_conflicts"] == 0


# --------------------------------------------------------------------------
# tiered store: publish + fencing (the control-plane contract)
# --------------------------------------------------------------------------

class TestTieredPublish:
    def test_publish_updates_hot_and_cold_rows_atomically(self):
        rng = np.random.default_rng(10)
        store, bank = _store(rng, t=16, hot=4, victims=2)
        hot_traffic = np.repeat(np.array([0, 1, 2, 3]), 4)
        raws16 = rng.uniform(0, 1, (16, 4)).astype(np.float32)
        store.dispatch(raws16, hot_traffic)
        store.rebalance()                              # 0..3 hot
        assert set(store.hot_rows()) == {0, 1, 2, 3}

        updates = {r: QuantileMap(np.sort(rng.uniform(0, 1, 32)),
                                  np.sort(rng.uniform(0, 1, 32)))
                   for r in (1, 9)}                    # one hot, one cold
        gen = store.apply_updates(updates)
        assert gen == 1 and store.generation == 1
        new_bank = bank.with_rows(updates, generation=1)
        tid = np.array([1, 9, 1, 9, 4, 0])             # hot+cold+untouched
        raws = rng.uniform(0, 1, (6, 4)).astype(np.float32)
        got, got_gen = store.dispatch(raws, tid)
        assert got_gen == 1
        assert _bitwise(got, _dense_scores(new_bank, raws, tid))

    def test_fenced_publish_rejects_stale_and_fast_forwards(self):
        rng = np.random.default_rng(11)
        store, _ = _store(rng, t=8)
        assert store.apply_updates({}, generation=5) == 5   # fast-forward
        with pytest.raises(StaleGenerationError):
            store.apply_updates({}, generation=5)           # not strictly newer
        with pytest.raises(StaleGenerationError):
            store.apply_updates(
                {0: QuantileMap.identity(32)}, generation=3)
        assert store.generation == 5
        assert store.apply_updates({}) == 5                 # empty unfenced noop

    def test_rebalance_fencing(self):
        rng = np.random.default_rng(12)
        store, _ = _store(rng, t=8)
        store.apply_updates({}, generation=4)
        with pytest.raises(StaleGenerationError):
            store.rebalance(generation=3)      # superseded control decision
        store.rebalance(generation=4)          # current stamp is fine
        store.rebalance()                      # and unfenced always is
        assert store.generation == 4           # rebalance never bumps

    def test_mark_cold_evicts_and_routes_through_prior(self):
        rng = np.random.default_rng(13)
        prior_src = np.sort(rng.uniform(0, 1, 32))
        prior = prior_bank_row(prior_src, np.linspace(0, 1, 32), 4)
        store, bank = _store(rng, t=8, hot=4, victims=2, prior=prior)
        raws = rng.uniform(0, 1, (8, 4)).astype(np.float32)
        tid = np.full(8, 2)
        store.dispatch(raws, tid)
        store.rebalance()
        assert 2 in store.hot_rows()
        store.mark_cold([2])
        assert 2 not in store.resident_rows()
        got, _ = store.dispatch(raws, tid)
        prior_bank = TransformBank.from_params([prior])
        want = _dense_scores(prior_bank, raws, np.zeros(8, np.int64))
        assert _bitwise(got, want)             # scored via the prior row
        assert store.metrics["prior_scores"] >= 8


# --------------------------------------------------------------------------
# cold start: Beta-mixture prior -> Eq.-5 gate -> admission -> promotion
# --------------------------------------------------------------------------

class TestColdStartIntegration:
    def test_new_tenant_scores_through_fitted_prior_then_promotes(self):
        """Satellite: no-history tenant serves the fitted Beta-mixture
        default quantiles; once its stream passes the Eq.-5 gate it is
        admitted and promoted, with bitwise parity against a dense bank."""
        from repro.core.coldstart import BetaMixtureFit
        rng = np.random.default_rng(14)
        fit = BetaMixtureFit(w=0.15, a0=2.0, b0=9.0, a1=7.0, b1=2.0,
                             jsd=0.0, moment_loss=0.0)
        ref = np.linspace(0.0, 1.0, 32) ** 1.5
        prior = prior_bank_row(fit, ref, num_experts=4)

        bank = _random_bank(rng, 8)
        admitted = np.ones(8, bool)
        admitted[5] = False                    # tenant 5 has no history
        host = HostBankStore.from_bank(bank, admitted=admitted)
        cfg = TieringConfig(hot_capacity=4, victim_capacity=2,
                            prior=prior, **EASY_GATE)
        store = TieredBankStore(host, cfg)
        assert store.gate_samples == required_sample_size(0.5, 1.0)

        raws = rng.uniform(0, 1, (2, 4)).astype(np.float32)
        tid = np.full(2, 5)
        got, _ = store.dispatch(raws, tid)     # 2 events < gate of 4
        prior_bank = TransformBank.from_params([prior])
        want_prior = _dense_scores(prior_bank, raws, np.zeros(2, np.int64))
        assert _bitwise(got, want_prior)
        store.rebalance()
        assert 5 not in store.hot_rows()       # still behind the gate

        got, _ = store.dispatch(raws, tid)     # 4 events total == gate
        assert _bitwise(got, want_prior)       # gate applies until rebalance
        res = store.rebalance()
        assert res["admitted"] == 1
        assert store.seen(5) >= store.gate_samples
        assert 5 in store.hot_rows()           # only recent traffic -> hot
        got, _ = store.dispatch(raws, tid)
        assert _bitwise(got, _dense_scores(bank, raws, tid))  # own row now

    def test_prior_row_from_raw_table_interpolates(self):
        src = np.sort(np.random.default_rng(15).uniform(0, 1, 16))
        ref = np.linspace(0, 1, 32)
        b, w, s, r = prior_bank_row(src, ref, num_experts=3)
        assert b.shape == (3,) and w.shape == (3,)
        assert s.shape == (32,) and r.shape == (32,)   # interpolated to ref
        assert np.all(np.diff(s) >= 0)

    def test_coldstart_module_importable_without_scipy(self):
        """Satellite: the scipy import is lazy — serving-only deployments
        construct BetaMixtureFit and build prior rows without scipy."""
        code = (
            "import sys\n"
            "class _Block:\n"
            "    def find_spec(self, name, path=None, target=None):\n"
            "        if name.split('.')[0] == 'scipy':\n"
            "            raise ImportError('scipy blocked')\n"
            "        return None\n"
            "sys.meta_path.insert(0, _Block())\n"
            "sys.modules.pop('scipy', None)\n"
            "from repro.core.coldstart import BetaMixtureFit, "
            "default_quantile_map\n"
            "fit = BetaMixtureFit(0.1, 2, 8, 8, 2, 0.0, 0.0)\n"
            "print('OK', fit.w)\n"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True,
                             env=env, cwd="/root/repo", timeout=120)
        assert out.returncode == 0, out.stderr
        assert "OK 0.1" in out.stdout


# --------------------------------------------------------------------------
# tiered server (sync data path)
# --------------------------------------------------------------------------

class TestTieredServer:
    def test_scores_match_dense_server(self):
        dense = _tenant_server(4)
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        reqs = [_req(f"t{i % 4}", seed=i) for i in range(12)]
        rd = dense.score_batch(list(reqs))
        rt = tiered.score_batch(list(reqs))
        for a, b in zip(rd, rt):
            assert a.score == b.score
            assert a.bank_generation == b.bank_generation == 0
        assert tiered.metrics["tier_dispatches"] >= 1
        assert tiered.tier_metrics()["events"] == 12

    def test_publish_then_parity_and_generation_stamp(self):
        rng = np.random.default_rng(16)
        dense = _tenant_server(4)
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        reqs = [_req(f"t{i % 4}", seed=i) for i in range(8)]
        dense.score_batch(list(reqs))
        tiered.score_batch(list(reqs))
        qm = QuantileMap(np.sort(rng.uniform(0, 1, 64)), REF64)
        upd = {"p1": qm, "p3": qm}
        assert dense.publish_quantile_maps(dict(upd)) == 1
        assert tiered.publish_quantile_maps(dict(upd)) == 1
        rd = dense.score_batch(list(reqs))
        rt = tiered.score_batch(list(reqs))
        for a, b in zip(rd, rt):
            assert a.score == b.score
            assert b.bank_generation == 1

    def test_fenced_publish_fast_forwards_tiered_stores(self):
        tiered = _tenant_server(2, tiering=_TIER_CFG)
        tiered.score_batch([_req("t0", 0)])
        tiered.publish_quantile_maps({}, generation=7)
        (store,) = tiered.tiered_stores().values()
        assert store.generation == 7
        resp = tiered.score_batch([_req("t0", 1)])[0]
        assert resp.bank_generation == 7
        with pytest.raises(StaleGenerationError):
            tiered.publish_quantile_maps({}, generation=7)

    def test_tiering_composes_with_sharding(self):
        # tiering + tenant_shards is the composed topology now (PR 9 lifted
        # the old mutual exclusion): the store behind the bank cache is the
        # per-shard-tiered ShardedTieredBankStore and scores stay bitwise-
        # equal to the dense server.  S=2 needs 2 devices (the tenant mesh
        # is built eagerly), so this runs under ./test.sh lanes; tier-1
        # single-device coverage is the S=1 composed path in
        # tests/test_tiered_sharded.py.
        if jax.device_count() < 2:
            pytest.skip("needs 2 devices (XLA_FLAGS host-device count)")
        from repro.serving.tiering import ShardedTieredBankStore
        rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                      for i in range(4)) + \
            (ScoringRule(Condition(), "p0"),)
        comp = MuseServer(RoutingTable(rules, version="v1"),
                          ServerConfig(tenant_shards=2, tiering=_TIER_CFG))
        for i in range(4):
            comp.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                      (1.0, 1.0), QuantileMap.identity(64)),
                        FACTORIES)
        dense = _tenant_server(4)
        reqs = [_req(f"t{i % 4}", seed=i) for i in range(12)]
        rd = dense.score_batch(list(reqs))
        rc = comp.score_batch(list(reqs))
        for a, b in zip(rd, rc):
            assert a.score == b.score
        (store,) = comp.tiered_stores().values()
        assert isinstance(store, ShardedTieredBankStore)
        assert store.num_shards == 2
        assert comp.metrics["shard_dispatches"] >= 1
        assert comp.metrics["tier_dispatches"] >= 1

    def test_decommission_drops_group_stores(self):
        tiered = _tenant_server(2, tiering=_TIER_CFG)
        tiered.score_batch([_req("t0", 0)])
        assert tiered.tiered_stores()
        tiered.decommission("p0")
        tiered.decommission("p1")
        assert not tiered.tiered_stores()

    def test_mark_cold_tenants_routes_through_prior(self):
        prior = prior_bank_row(np.linspace(0, 1, 64) ** 2, REF64, 2)
        cfg = TieringConfig(hot_capacity=3, victim_capacity=2,
                            prior=prior, **EASY_GATE)
        tiered = _tenant_server(4, tiering=cfg)
        dense = _tenant_server(4)
        # a dense twin whose p2 predictor IS the prior row (beta=1,
        # uniform weights, the prior's T^Q)
        oracle = _tenant_server(4)
        oracle.deploy(
            PredictorSpec("p2", ("m1", "m2"), (1.0, 1.0), (1.0, 1.0),
                          QuantileMap(np.asarray(prior[2], np.float64),
                                      np.asarray(prior[3], np.float64))),
            FACTORIES)
        tiered.mark_cold_tenants(["p2"])
        reqs = [_req("t2", seed=i) for i in range(3)]
        rt = tiered.score_batch(list(reqs))
        ro = oracle.score_batch(list(reqs))
        rd = dense.score_batch(list(reqs))
        for a, b, c in zip(rt, ro, rd):
            assert a.score == pytest.approx(b.score, abs=1e-7)
            assert a.score != c.score          # genuinely the prior, not row
        # its estimator stream still tracks (through the prior's pre-Q path)
        assert ("t2", "p2") in tiered.estimator_streams()

    def test_server_prefetch_endpoint(self):
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        # one 4-predictor window builds the ("p0".."p3") store; the
        # multi-pass dispatch leaves rows {2, 3} in the victim cache
        tiered.score_batch([_req(f"t{i}", i) for i in range(4)])
        assert tiered.prefetch_enabled
        names = ["p0", "p1", "p2", "p3"]
        assert tiered.prefetch_transforms(names, create=False) == 2  # 0, 1
        # that prefetch evicted 2 and 3 — the same call stages them back
        assert tiered.prefetch_transforms(names, create=False) == 2
        (store,) = tiered.tiered_stores().values()
        assert store.metrics["prefetched_rows"] == 4
        # the poll path never creates stores for unseen predictor subsets
        assert tiered.prefetch_transforms(["p0"], create=False) == 0
        dense = _tenant_server(2)
        assert not dense.prefetch_enabled


# --------------------------------------------------------------------------
# calibration refresh through the tiers (single server, fast path)
# --------------------------------------------------------------------------

class TestTieredCalibrationRefresh:
    def test_refresh_updates_hot_cold_and_promotes_admitted(self):
        rng = np.random.default_rng(17)
        gate = required_sample_size(0.05, 0.5)
        dense = _tenant_server(3)
        tiered = _tenant_server(3, tiering=_TIER_CFG)
        streams = {f"p{i}": rng.uniform(0, 1, gate + 50) for i in range(3)}
        for i in range(3):
            _inject(dense, f"t{i}", f"p{i}", streams[f"p{i}"], seed=i)
            _inject(tiered, f"t{i}", f"p{i}", streams[f"p{i}"], seed=i)
        reqs = [_req(f"t{i % 3}", seed=i) for i in range(9)]
        dense.score_batch(list(reqs))
        tiered.score_batch(list(reqs))

        rd = CalibrationController(dense, REF64, _policy()).refresh_fleet()
        rt = CalibrationController(tiered, REF64, _policy()).refresh_fleet()
        keys = {(r.tenant, r.predictor) for r in rt.refreshed}
        assert keys == {(r.tenant, r.predictor) for r in rd.refreshed}
        assert keys
        assert tiered.bank_generation == dense.bank_generation

        out_d = dense.score_batch(list(reqs))
        out_t = tiered.score_batch(list(reqs))
        for a, b in zip(out_d, out_t):
            assert a.score == b.score
            assert b.bank_generation == tiered.bank_generation
        # the controller ran a post-publish rebalance: refreshed tenants
        # with traffic now hold hot slots
        (store,) = tiered.tiered_stores().values()
        assert store.metrics["promotions"] >= 1


# ==========================================================================
# tiering-marked campaigns
# ==========================================================================

@pytest.mark.tiering
class TestPromoteDemotePublishProperty:
    """Acceptance: across random promote/demote/publish/mark-cold schedules,
    a post-publish read of ANY tenant serves the new generation's params —
    bitwise equal to a dense bank rebuilt from the authoritative host rows,
    stamped with the store's current generation."""

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_random_schedule_serves_current_generation(self, seed):
        rng = np.random.default_rng(seed)
        t, k, n = 24, 2, 16
        bank = _random_bank(rng, t, k=k, n=n)
        admitted = rng.random(t) > 0.25
        store = TieredBankStore(
            HostBankStore.from_bank(bank, admitted=admitted.copy()),
            TieringConfig(hot_capacity=int(rng.integers(2, 8)),
                          victim_capacity=int(rng.integers(2, 5)),
                          fused_kernel=False, **EASY_GATE))
        prior_tab = (np.asarray(store._view.src_quantiles[-1]),
                     np.asarray(store._view.ref_quantiles[-1]))

        def oracle() -> TransformBank:
            return store.host.dense_bank(store.generation)

        for _ in range(25):
            op = rng.integers(0, 5)
            if op == 0:                                  # dispatch a window
                b = int(rng.integers(1, 12))
                tid = rng.integers(0, t, b)
                raws = rng.uniform(0, 1, (b, k)).astype(np.float32)
                got, gen = store.dispatch(raws, tid)
                assert gen == store.generation
                dense = oracle()
                adm = store.host.admitted[tid]
                eff_tid = np.where(adm, tid, 0)
                want = _dense_scores(dense, raws, eff_tid, fused=False)
                prior_want = _dense_scores(
                    TransformBank.from_params(
                        [(np.ones(k), np.ones(k)) + prior_tab]),
                    raws, np.zeros(b, np.int64), fused=False)
                want = np.where(adm, want, prior_want)
                assert _bitwise(got, want)
            elif op == 1:                                # rebalance
                store.rebalance()
            elif op == 2:                                # publish
                rows = rng.choice(t, size=int(rng.integers(1, 5)),
                                  replace=False)
                updates = {int(r): QuantileMap(
                    np.sort(rng.uniform(0, 1, n)),
                    np.sort(rng.uniform(0, 1, n))) for r in rows}
                before = store.generation
                assert store.apply_updates(updates) == before + 1
            elif op == 3:                                # mark cold
                store.mark_cold([int(rng.integers(0, t))])
            else:                                        # prefetch
                store.prefetch(rng.integers(0, t, 6))
            # structural invariants: slot maps stay a bijection
            owners = store._owner[store._owner >= 0]
            assert len(owners) == len(set(owners.tolist()))
            for tid_ in owners:
                assert store._owner[store._slot_of[tid_]] == tid_

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_fenced_interleaving_monotone_generations(self, seed):
        rng = np.random.default_rng(seed)
        store, _ = _store(np.random.default_rng(seed), t=12, hot=4,
                          victims=2, fused_kernel=False)
        gen = 0
        for _ in range(20):
            target = gen + int(rng.integers(-2, 4))
            try:
                store.apply_updates(
                    {int(rng.integers(0, 12)): QuantileMap.identity(32)}
                    if rng.random() < 0.5 else {},
                    generation=target)
                assert target > gen            # accepted => strictly newer
                gen = target
            except StaleGenerationError:
                assert target <= gen           # rejected => stale stamp
            assert store.generation == gen


@pytest.mark.tiering
class TestTieredFleetRefresh:
    def test_fleet_publish_lands_in_both_tiers_of_every_replica(self):
        rng = np.random.default_rng(18)
        gate = required_sample_size(0.05, 0.5)
        reps = [Replica(i, _tenant_server(3, tiering=_TIER_CFG), "v1",
                        ready=True) for i in range(2)]
        rs = ReplicaSet(reps)
        reqs = [_req(f"t{i % 3}", seed=i) for i in range(9)]
        for rep in reps:
            rep.server.score_batch(list(reqs))
            for i in range(3):
                _inject(rep.server, f"t{i}", f"p{i}",
                        rng.uniform(0, 1, gate // 2 + 40), seed=i)
        fleet = FleetCalibrationController(rs, REF64, _policy())
        res = fleet.refresh_fleet()
        assert res.refreshed and not res.nacked
        gens = {rep.server.bank_generation for rep in reps}
        assert len(gens) == 1                  # converged fleet generation
        gen = gens.pop()
        outs = [rep.server.score_batch(list(reqs)) for rep in reps]
        for a, b in zip(*outs):
            assert a.score == b.score          # replicas agree post-publish
            assert a.bank_generation == gen
        for rep in reps:
            (store,) = rep.server.tiered_stores().values()
            assert store.generation == gen


@pytest.mark.tiering
class TestEnginePrefetch:
    def test_poll_prefetches_pending_window_rows(self):
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        # build the ("p0".."p3") store the pending mixed window will key to
        tiered.score_batch([_req(f"t{i}", i) for i in range(4)])
        (store,) = tiered.tiered_stores().values()
        base_staged = store.metrics["prefetched_rows"]
        engine = AsyncDispatchEngine(tiered, max_batch=64, max_wait_ms=1e9)
        assert engine._prefetchable
        try:
            futs = [engine.submit(_req(f"t{i % 4}", seed=i))
                    for i in range(8)]
            engine.poll()                      # window still accumulating
            assert store.metrics["prefetched_rows"] > base_staged
            engine.flush()
            scores = [f.result(timeout=60).score for f in futs]
        finally:
            engine.close()
        dense = _tenant_server(4)
        want = [r.score for r in dense.score_batch(
            [_req(f"t{i % 4}", seed=i) for i in range(8)])]
        assert scores == want

    def test_poll_counts_unexpected_prefetch_faults(self, monkeypatch):
        """A real prefetch bug (bad tenant id, torn store ref) must not be
        swallowed silently: poll survives, but the fault is counted in
        ``prefetch_errors`` and lands in ``errors``."""
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        tiered.score_batch([_req(f"t{i}", i) for i in range(4)])
        engine = AsyncDispatchEngine(tiered, max_batch=64, max_wait_ms=1e9)
        try:
            engine.submit(_req("t1", seed=0))

            def boom(names, plane=None, *, create=False):
                raise IndexError("torn store ref")

            monkeypatch.setattr(tiered, "prefetch_transforms", boom)
            engine.poll()                      # must not raise
            assert engine.prefetch_errors == 1
            assert any(isinstance(e, IndexError) for _, e in engine.errors)
            # the window itself still dispatches (prefetch is best-effort)
            monkeypatch.undo()
            engine.flush()
            engine.drain()
        finally:
            engine.close()

    def test_poll_ignores_expected_dispatch_race(self, monkeypatch):
        """KeyError is the expected race (window dispatched / predictor
        undeployed between the locked collection and the prefetch call):
        not an error, not counted."""
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        tiered.score_batch([_req(f"t{i}", i) for i in range(4)])
        engine = AsyncDispatchEngine(tiered, max_batch=64, max_wait_ms=1e9)
        try:
            engine.submit(_req("t1", seed=0))

            def race(names, plane=None, *, create=False):
                raise KeyError("p1")

            monkeypatch.setattr(tiered, "prefetch_transforms", race)
            engine.poll()
            assert engine.prefetch_errors == 0
            assert not engine.errors
        finally:
            engine.close()

    def test_model_stage_prefetch_fault_counted_window_survives(
            self, monkeypatch):
        """The model stage's create=True prefetch: an unexpected fault is
        counted but the window still scores (paying the stall the
        prefetch would have hidden); the expected KeyError race stays
        uncounted."""
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        real = tiered.prefetch_transforms
        mode = {"exc": ValueError("bad tenant id")}

        def flaky(names, plane=None, *, create=False):
            if create and mode["exc"] is not None:
                raise mode["exc"]
            return real(names, plane, create=create)

        monkeypatch.setattr(tiered, "prefetch_transforms", flaky)
        engine = AsyncDispatchEngine(tiered, max_batch=4, max_wait_ms=1e9)
        try:
            futs = [engine.submit(_req(f"t{i}", seed=i)) for i in range(4)]
            engine.flush()
            scores = [f.result(timeout=60).score for f in futs]
            assert engine.prefetch_errors == 1
            assert any(isinstance(e, ValueError) for _, e in engine.errors)
            dense = _tenant_server(4)
            want = [r.score for r in dense.score_batch(
                [_req(f"t{i}", seed=i) for i in range(4)])]
            assert scores == want              # window served regardless
            mode["exc"] = KeyError("p0")       # expected race: uncounted
            futs = [engine.submit(_req(f"t{i}", seed=10 + i))
                    for i in range(4)]
            engine.flush()
            for f in futs:
                f.result(timeout=60)
            assert engine.prefetch_errors == 1
        finally:
            engine.close()

    def test_engine_pipeline_stalls_only_before_prefetch_lands(self):
        """Through the full engine pipeline the model stage's create=True
        prefetch staging means the transform stage's dispatch finds its
        rows resident (no cold-miss stalls after the first window)."""
        tiered = _tenant_server(4, tiering=_TIER_CFG)
        engine = AsyncDispatchEngine(tiered, max_batch=4, max_wait_ms=1e9)
        try:
            futs = [engine.submit(_req(f"t{i}", seed=i)) for i in range(4)]
            engine.flush()
            for f in futs:
                f.result(timeout=60)
            (store,) = tiered.tiered_stores().values()
            first = store.metrics["stalled_events"]
            tiered.rebalance_tiers()           # hot set = 3 of the 4 rows
            for batch in range(1, 4):
                futs = [engine.submit(_req(f"t{i}", seed=batch * 4 + i))
                        for i in range(4)]
                engine.flush()
                for f in futs:
                    f.result(timeout=60)
            # the model stage's create=True prefetch stages the sole
            # non-hot row before each window's dispatch: no new stalls
            assert store.metrics["stalled_events"] == first
            assert store.metrics["prefetched_rows"] >= 1
        finally:
            engine.close()


@pytest.mark.tiering
class TestRolloutWarmStart:
    def test_warm_tiers_from_adopts_hot_set(self):
        old = _tenant_server(4, tiering=_TIER_CFG)
        # one window over all four predictors, traffic concentrated on t1/t2
        reqs = [_req("t1", seed=i) for i in range(3)] + \
            [_req("t2", seed=i + 100) for i in range(3)] + \
            [_req("t0", seed=200), _req("t3", seed=201)]
        old.score_batch(list(reqs))
        old.rebalance_tiers()
        (old_store,) = old.tiered_stores().values()
        assert {1, 2} <= set(old_store.hot_rows())

        new = _tenant_server(4, tiering=_TIER_CFG, version="v2")
        assert new.warm_tiers_from(old) == 1
        (new_store,) = new.tiered_stores().values()
        assert set(new_store.hot_rows()) == set(old_store.hot_rows())
        # the adopted hot set serves the concentrated mix from hot slots;
        # only the single non-hot row can page (2 of its events at most)
        new.score_batch(list(reqs))
        assert new_store.metrics["hot_hits"] >= 6
        assert new_store.metrics["stalled_events"] <= 2
        # non-tiered servers are a no-op on either side
        assert _tenant_server(2).warm_tiers_from(old) == 0
        assert new.warm_tiers_from(_tenant_server(2)) == 0  # dense source

    def test_rolling_update_warms_surged_replicas(self):
        def make(version):
            return _tenant_server(3, tiering=_TIER_CFG, version=version)

        reps = [Replica(i, make("v1"), "v1", ready=True) for i in range(2)]
        rs = ReplicaSet(reps)
        seed_reqs = [_req(f"t{i % 3}", seed=i) for i in range(12)]
        for rep in reps:
            rep.server.score_batch(list(seed_reqs))
            rep.server.rebalance_tiers()
        update = RollingUpdate(rs, lambda: make("v2"), "v2",
                               schema_dim=DIM, warmup_batch_sizes=(1, 2))

        def traffic():
            i = 0
            while True:
                yield [_req(f"t{i % 3}", seed=i), _req(f"t{(i+1) % 3}",
                                                       seed=i + 1)]
                i += 2

        update.run_with_traffic(traffic(), batches_per_transition=1)
        assert all(rep.version == "v2" for rep in rs.replicas)
        for rep in rs.replicas:
            stores = rep.server.tiered_stores()
            assert stores                      # surged replicas are tiered
            # warm start: the victim's hotness was adopted, so at least one
            # group store promoted a hot set instead of starting from zero
            assert any(len(s.hot_rows()) >= 1 for s in stores.values())
