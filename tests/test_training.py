"""Training substrate tests: optimizer, data pipelines, loop, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.training.checkpoint import (
    latest_step,
    load_metadata,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.data import (
    FraudEventStream,
    TenantProfile,
    TokenStream,
    fit_logistic_expert,
    logistic_expert_scores,
)
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train import Trainer, make_train_step


class TestAdamW:
    def test_quadratic_convergence(self):
        opt = AdamW(learning_rate=0.1, weight_decay=0.0, grad_clip_norm=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=1e-2)

    def test_grad_clipping(self):
        opt = AdamW(learning_rate=1.0, grad_clip_norm=1e-6, weight_decay=0.0)
        params = {"w": jnp.array([1.0])}
        state = opt.init(params)
        new_params, _ = opt.update({"w": jnp.array([1e9])}, state, params)
        # effective grad clipped to 1e-6 -> bias-corrected Adam still takes a
        # bounded step of ~lr; must not explode to 1e9 scale
        assert abs(float(new_params["w"][0]) - 1.0) < 2.0

    def test_bf16_moments(self):
        opt = AdamW(learning_rate=0.01, moment_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((4, 4))}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.bfloat16
        new_params, state = opt.update({"w": jnp.ones((4, 4))}, state, params)
        assert np.isfinite(np.asarray(new_params["w"])).all()

    def test_cosine_schedule(self):
        sched = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
        assert float(sched(jnp.asarray(0))) == 0.0
        assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
        assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
        mid = float(sched(jnp.asarray(55)))
        assert 1e-4 < mid < 1e-3


class TestFraudStream:
    def test_fraud_rate(self):
        stream = FraudEventStream(TenantProfile("t", fraud_rate=0.02, seed=0))
        _, y = stream.sample(200_000)
        assert y.mean() == pytest.approx(0.02, rel=0.1)

    def test_undersampling_shifts_prior(self):
        stream = FraudEventStream(TenantProfile("t", fraud_rate=0.01, seed=1))
        _, y_full = stream.sample(100_000)
        _, y_under = stream.sample_undersampled(50_000, beta=0.05)
        # undersampling negatives at 5% inflates the positive rate ~17x
        assert y_under.mean() > 8 * y_full.mean()

    def test_bayes_posterior_is_calibrated(self):
        stream = FraudEventStream(TenantProfile("t", fraud_rate=0.05, seed=2))
        x, y = stream.sample(300_000)
        p = stream.bayes_posterior(x)
        from repro.core.metrics import ece_sweep_em
        assert ece_sweep_em(p, y) < 0.01

    def test_expert_learns_biased_posterior(self):
        """An expert trained on beta-undersampled data approximates the
        *biased* posterior; Posterior Correction recovers the true one."""
        from repro.core.transforms import posterior_correction
        from repro.core.metrics import brier_score
        stream = FraudEventStream(TenantProfile("t", fraud_rate=0.01, seed=3))
        beta = 0.05
        x_tr, y_tr = stream.sample_undersampled(120_000, beta=beta)
        w, b = fit_logistic_expert(x_tr, y_tr)
        x_te, y_te = stream.sample(200_000)
        raw = logistic_expert_scores(x_te, w, b)
        corrected = np.asarray(posterior_correction(jnp.asarray(raw), beta))
        assert brier_score(corrected, y_te) < brier_score(raw, y_te)


class TestTokenStream:
    def test_shapes_and_determinism(self):
        s1 = iter(TokenStream(vocab_size=256, seq_len=32, batch_size=4, seed=5))
        s2 = iter(TokenStream(vocab_size=256, seq_len=32, batch_size=4, seed=5))
        t1, l1 = next(s1)
        t2, l2 = next(s2)
        assert t1.shape == (4, 32) and l1.shape == (4, 32)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])

    def test_vocab_bounds(self):
        t, l = next(iter(TokenStream(vocab_size=64, seq_len=16, batch_size=8)))
        assert t.min() >= 0 and t.max() < 64


class TestTrainerEndToEnd:
    def test_loss_decreases_over_short_run(self):
        cfg = get_smoke_config("internlm2-1.8b")
        model = Model(cfg)
        trainer = Trainer(model, AdamW(learning_rate=5e-3), remat=False,
                          compute_dtype=jnp.float32)
        state = trainer.init_state(jax.random.key(0))
        stream = iter(TokenStream(cfg.vocab_size, seq_len=32, batch_size=16))
        state, history = trainer.fit(state, stream, num_steps=60, log_every=1,
                                     log_fn=lambda *_: None)
        first, last = history[0]["loss"], history[-1]["loss"]
        # from ~uniform ln(512)=6.24 down to ~unigram entropy (~4.4)
        assert last < first - 1.0, f"loss {first} -> {last}: no learning"

    def test_train_step_jit_donation(self):
        cfg = get_smoke_config("olmoe-1b-7b")
        model = Model(cfg)
        opt = AdamW(learning_rate=1e-3)
        step = jax.jit(make_train_step(model, opt, remat=True),
                       donate_argnums=(0,))
        from repro.training.train import TrainState
        params = model.init(jax.random.key(0))
        state = TrainState(params, opt.init(params))
        toks = jnp.zeros((2, 16), jnp.int32)
        state, metrics = step(state, toks, toks)
        assert np.isfinite(float(metrics.loss))
        assert float(metrics.moe_aux) > 0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": [{"b": jnp.ones((4,), jnp.bfloat16)},
                       {"b": jnp.zeros((4,), jnp.bfloat16)}],
        }
        save_checkpoint(str(tmp_path), 7, tree, {"note": "test"})
        like = jax.tree.map(jnp.zeros_like, tree)
        restored = restore_checkpoint(str(tmp_path), 7, like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype
        assert load_metadata(str(tmp_path), 7)["note"] == "test"
        assert latest_step(str(tmp_path)) == 7

    def test_model_params_roundtrip(self, tmp_path):
        cfg = get_smoke_config("jamba-1.5-large-398b")
        model = Model(cfg)
        params = model.init(jax.random.key(1))
        save_checkpoint(str(tmp_path), 1, params)
        restored = restore_checkpoint(str(tmp_path), 1,
                                      jax.tree.map(jnp.zeros_like, params))
        out1 = model.forward(restored, tokens=jnp.zeros((1, 8), jnp.int32))
        out2 = model.forward(params, tokens=jnp.zeros((1, 8), jnp.int32))
        np.testing.assert_array_equal(np.asarray(out1.logits, np.float32),
                                      np.asarray(out2.logits, np.float32))

    def test_missing_leaf_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            restore_checkpoint(str(tmp_path), 0,
                               {"a": jnp.zeros(3), "b": jnp.zeros(2)})
