"""Mixed-tenant banked score pipeline: kernel/oracle parity + serving path.

The banked kernel must match the per-tenant ``core/transforms.py::
score_pipeline`` oracle row-for-row on batches spanning many tenants with
distinct betas / weights / quantile maps — including degenerate (flat)
source segments, scores outside the fitted support, and single-tenant banks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import (
    QuantileMap,
    TransformBank,
    banked_score_pipeline,
    score_pipeline,
)
from repro.kernels import ops
from repro.serving.batching import MicroBatcher, ServerBatcher
from repro.serving.server import MuseServer, ServerConfig
from repro.serving.types import ScoringRequest

TOL = 1e-5


def _random_bank(rng, t, k, n):
    betas = rng.uniform(0.05, 1.0, (t, k)).astype(np.float32)
    weights = rng.uniform(0.1, 2.0, (t, k)).astype(np.float32)
    src = np.sort(rng.uniform(0.0, 1.0, (t, n)), axis=-1).astype(np.float32)
    ref = np.sort(rng.uniform(0.0, 1.0, (t, n)), axis=-1).astype(np.float32)
    return TransformBank(
        betas=jnp.asarray(betas), weights=jnp.asarray(weights),
        src_quantiles=jnp.asarray(src), ref_quantiles=jnp.asarray(ref),
    )


def _per_tenant_oracle(bank, scores, tid):
    """Row-by-row reference through the SINGLE-tenant Eq. 2 oracle."""
    out = np.empty(scores.shape[0], np.float32)
    tid = np.asarray(tid)
    for t in np.unique(tid):
        m = tid == t
        out[m] = np.asarray(score_pipeline(
            jnp.asarray(scores[m]), bank.betas[t], bank.weights[t],
            bank.src_quantiles[t], bank.ref_quantiles[t]))
    return out


class TestBankedKernelParity:
    @pytest.mark.parametrize("t,k,n,b", [(3, 2, 32, 97), (8, 4, 64, 1000),
                                         (64, 4, 256, 2048)])
    def test_mixed_tenant_matches_per_tenant_oracles(self, t, k, n, b):
        rng = np.random.default_rng(t * 1000 + b)
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0.0, 1.0, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b).astype(np.int32)

        got = np.asarray(ops.score_pipeline_banked(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))
        np.testing.assert_allclose(got, _per_tenant_oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)
        # and the pure-jnp banked oracle agrees with the kernel too
        np.testing.assert_allclose(
            got, np.asarray(bank(jnp.asarray(scores), jnp.asarray(tid))),
            atol=TOL, rtol=TOL)

    def test_flat_source_segments(self):
        """Repeated source knots (degenerate segments) must not divide by 0
        and must still match the per-tenant oracle."""
        rng = np.random.default_rng(7)
        t, k, n, b = 4, 3, 16, 512
        bank = _random_bank(rng, t, k, n)
        src = np.array(bank.src_quantiles)
        src[:, 4:9] = src[:, 4:5]         # 5-knot plateau in every tenant
        src[1, :] = 0.5                   # tenant 1: fully degenerate table
        bank = TransformBank(
            betas=bank.betas, weights=bank.weights,
            src_quantiles=jnp.asarray(src), ref_quantiles=bank.ref_quantiles)
        scores = rng.uniform(0.0, 1.0, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b).astype(np.int32)
        got = np.asarray(ops.score_pipeline_banked(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, _per_tenant_oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)

    def test_scores_outside_fitted_support(self):
        """Aggregates left/right of [q^S_1, q^S_N] clip to the ref support."""
        rng = np.random.default_rng(11)
        t, k, n = 3, 2, 32
        betas = jnp.ones((t, k), jnp.float32)          # identity T^C
        weights = jnp.ones((t, k), jnp.float32)
        src = np.sort(rng.uniform(0.4, 0.6, (t, n)), axis=-1).astype(np.float32)
        ref = np.sort(rng.uniform(0.2, 0.8, (t, n)), axis=-1).astype(np.float32)
        bank = TransformBank(betas=betas, weights=weights,
                             src_quantiles=jnp.asarray(src),
                             ref_quantiles=jnp.asarray(ref))
        # aggregates far below and above every tenant's source support
        scores = np.concatenate([np.full((64, k), 0.01, np.float32),
                                 np.full((64, k), 0.99, np.float32)])
        tid = np.tile(np.arange(t, dtype=np.int32), 128 // t + 1)[:128]
        got = np.asarray(ops.score_pipeline_banked(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))
        np.testing.assert_allclose(got, _per_tenant_oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)
        lo = ref[tid, 0]
        hi = ref[tid, -1]
        assert (got >= lo - TOL).all() and (got <= hi + TOL).all()
        np.testing.assert_allclose(got[:64], lo[:64], atol=TOL)
        np.testing.assert_allclose(got[64:], hi[64:], atol=TOL)

    def test_single_tenant_bank(self):
        rng = np.random.default_rng(3)
        bank = _random_bank(rng, 1, 4, 64)
        scores = rng.uniform(0.0, 1.0, (33, 4)).astype(np.float32)
        tid = np.zeros(33, np.int32)
        got = np.asarray(ops.score_pipeline_banked(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))
        want = np.asarray(score_pipeline(
            jnp.asarray(scores), bank.betas[0], bank.weights[0],
            bank.src_quantiles[0], bank.ref_quantiles[0]))
        np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)

    def test_tenant_idx_length_mismatch_raises(self):
        bank = _random_bank(np.random.default_rng(0), 2, 2, 8)
        with pytest.raises(ValueError):
            ops.score_pipeline_banked(
                jnp.zeros((4, 2)), jnp.zeros((3,), jnp.int32), bank.betas,
                bank.weights, bank.src_quantiles, bank.ref_quantiles)


class TestScalarPrefetchBankedKernel:
    """Regression campaign for the prefetched banked kernel: the per-block
    (block_tenant, block_uniform) scalars ride in ``PrefetchScalarGridSpec``
    and all-one-tenant blocks skip the one-hot gather matmuls.  Both paths
    must match the pure-jnp ``banked_score_pipeline`` oracle, and the fast
    path must agree with the one-hot path BITWISE (the sharded serving
    topology re-buckets rows, which flips blocks between the two paths)."""

    BLOCK = 64  # small block -> many grid blocks at test scale

    def _oracle(self, bank, scores, tid):
        return np.asarray(banked_score_pipeline(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))

    def _kernel(self, bank, scores, tid):
        return np.asarray(ops.score_pipeline_banked(
            jnp.asarray(scores), jnp.asarray(tid), bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles,
            block=self.BLOCK))

    def test_all_uniform_blocks_take_fast_path_and_match_oracle(self):
        from repro.kernels.score_pipeline import banked_skip_stats
        rng = np.random.default_rng(21)
        t, k, n, b = 6, 3, 32, 6 * 64
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        # block-aligned tenant runs: EVERY block is all-one-tenant
        tid = np.repeat(np.arange(t, dtype=np.int32), 64)
        stats = banked_skip_stats(tid, block=self.BLOCK)
        assert stats == {"block": 64, "blocks": 6, "uniform_blocks": 6,
                         "skip_rate": 1.0}
        got = self._kernel(bank, scores, tid)
        np.testing.assert_allclose(got, self._oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)

    def test_adversarial_interleave_never_skips_and_matches_oracle(self):
        from repro.kernels.score_pipeline import banked_skip_stats
        rng = np.random.default_rng(22)
        t, k, n, b = 5, 2, 16, 4 * 64
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        # adversarial layout: tenants alternate row by row — every block
        # mixes all tenants, the one-hot path runs for the whole batch
        tid = (np.arange(b) % t).astype(np.int32)
        stats = banked_skip_stats(tid, block=self.BLOCK)
        assert stats["uniform_blocks"] == 0 and stats["skip_rate"] == 0.0
        got = self._kernel(bank, scores, tid)
        np.testing.assert_allclose(got, self._oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)

    def test_mixed_layout_skips_exactly_the_uniform_blocks(self):
        from repro.kernels.score_pipeline import banked_skip_stats
        # blocks: [all-2s] [mixed] [all-0s] [mixed]
        tid = np.concatenate([
            np.full(64, 2), np.arange(64) % 3,
            np.zeros(64), np.arange(64) % 2]).astype(np.int32)
        stats = banked_skip_stats(tid, block=self.BLOCK)
        assert stats["blocks"] == 4
        assert stats["uniform_blocks"] == 2
        assert stats["skip_rate"] == 0.5
        rng = np.random.default_rng(23)
        bank = _random_bank(rng, 3, 2, 16)
        scores = rng.uniform(0, 1, (len(tid), 2)).astype(np.float32)
        got = self._kernel(bank, scores, tid)
        np.testing.assert_allclose(got, self._oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)

    def test_fast_and_onehot_paths_agree_bitwise(self):
        """The SAME rows scored under a uniform-block layout (fast path)
        and embedded in an adversarial layout (one-hot path) must produce
        bit-identical f32 scores — the dense/sharded bitwise-parity
        invariant depends on it."""
        rng = np.random.default_rng(24)
        t, k, n = 4, 3, 32
        bank = _random_bank(rng, t, k, n)
        rows = rng.uniform(0, 1, (64, k)).astype(np.float32)
        # (a) alone: one uniform block for tenant 1 -> fast path
        alone = self._kernel(bank, rows, np.full(64, 1, np.int32))
        # (b) interleaved with other tenants at 2x block size -> both
        # blocks mixed -> one-hot path for the same 64 rows
        other = rng.uniform(0, 1, (64, k)).astype(np.float32)
        inter_scores = np.empty((128, k), np.float32)
        inter_tid = np.empty(128, np.int32)
        inter_scores[0::2], inter_scores[1::2] = rows, other
        inter_tid[0::2], inter_tid[1::2] = 1, (np.arange(64) % t)
        from repro.kernels.score_pipeline import banked_skip_stats
        assert banked_skip_stats(inter_tid, block=self.BLOCK)["skip_rate"] == 0
        mixed = self._kernel(bank, inter_scores, inter_tid)[0::2]
        assert np.array_equal(alone.view(np.uint32), mixed.view(np.uint32))

    def test_edge_padded_partial_tail_block(self):
        """A final partial block edge-pads its tenant vector: a uniform
        tail stays on the fast path and padded rows never leak out."""
        from repro.kernels.score_pipeline import banked_skip_stats
        rng = np.random.default_rng(25)
        t, k, n, b = 3, 2, 16, 64 + 17      # 17-row tail, all tenant 2
        bank = _random_bank(rng, t, k, n)
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid = np.concatenate([np.arange(64) % t,
                              np.full(17, 2)]).astype(np.int32)
        stats = banked_skip_stats(tid, block=self.BLOCK)
        assert stats["blocks"] == 2 and stats["uniform_blocks"] == 1
        got = self._kernel(bank, scores, tid)
        assert got.shape == (b,)
        np.testing.assert_allclose(got, self._oracle(bank, scores, tid),
                                   atol=TOL, rtol=TOL)


class TestFromParams:
    def test_ragged_expert_and_quantile_axes_pad_exactly(self):
        """Rows with fewer experts / knots pad with identity columns and
        edge-repeated knots — padded rows score identically to unpadded."""
        rng = np.random.default_rng(5)
        q8 = QuantileMap(
            src_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, 8)), jnp.float32),
            ref_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, 8)), jnp.float32))
        q16 = QuantileMap(
            src_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, 16)), jnp.float32),
            ref_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, 16)), jnp.float32))
        params = [
            (jnp.asarray([0.2, 0.5]), jnp.asarray([1.0, 3.0]),
             q8.src_quantiles, q8.ref_quantiles),
            (jnp.asarray([0.9]), jnp.asarray([2.0]),
             q16.src_quantiles, q16.ref_quantiles),
        ]
        bank = TransformBank.from_params(params)
        assert bank.num_rows == 2
        assert bank.num_experts == 2
        assert bank.num_quantiles == 16

        scores2 = rng.uniform(0, 1, (50, 2)).astype(np.float32)
        want0 = np.asarray(score_pipeline(
            jnp.asarray(scores2), params[0][0], params[0][1],
            q8.src_quantiles, q8.ref_quantiles))
        got0 = np.asarray(banked_score_pipeline(
            jnp.asarray(scores2), jnp.zeros(50, jnp.int32), bank.betas,
            bank.weights, bank.src_quantiles, bank.ref_quantiles))
        np.testing.assert_allclose(got0, want0, atol=TOL, rtol=TOL)

        # single-expert row: padded column has weight 0, so column 1 is inert
        want1 = np.asarray(score_pipeline(
            jnp.asarray(scores2[:, :1]), params[1][0], params[1][1],
            q16.src_quantiles, q16.ref_quantiles))
        got1 = np.asarray(banked_score_pipeline(
            jnp.asarray(scores2), jnp.ones(50, jnp.int32), bank.betas,
            bank.weights, bank.src_quantiles, bank.ref_quantiles))
        np.testing.assert_allclose(got1, want1, atol=TOL, rtol=TOL)

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            TransformBank.from_params([])


# ---------------------------------------------------------------------------
# Serving-path integration: mixed-tenant batches through MuseServer
# ---------------------------------------------------------------------------

DIM = 8


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


def _qm(seed: int, n: int = 32) -> QuantileMap:
    rng = np.random.default_rng(seed)
    return QuantileMap(
        src_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, n)), jnp.float32),
        ref_quantiles=jnp.asarray(np.sort(rng.uniform(0, 1, n)), jnp.float32))


def _mixed_server(**cfg) -> MuseServer:
    """3 tenants -> 3 predictors; a/b share a model group, c has its own."""
    rules = (ScoringRule(Condition(tenants=("ta",)), "p-a"),
             ScoringRule(Condition(tenants=("tb",)), "p-b"),
             ScoringRule(Condition(), "p-c"))
    server = MuseServer(RoutingTable(rules, version="v1"),
                        ServerConfig(**cfg))
    factories = {"m1": lambda: _linear_model(1), "m2": lambda: _linear_model(2),
                 "m3": lambda: _linear_model(3)}
    server.deploy(PredictorSpec("p-a", ("m1", "m2"), (0.2, 0.4), (1.0, 2.0),
                                _qm(10)), factories)
    server.deploy(PredictorSpec("p-b", ("m1", "m2"), (0.5, 0.9), (3.0, 1.0),
                                _qm(20)), factories)
    server.deploy(PredictorSpec.single("p-c", "m3", _qm(30)), factories)
    return server


def _req(tenant, seed):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


class TestServerBankedPath:
    def test_mixed_batch_one_dispatch_per_model_group(self):
        server = _mixed_server()
        reqs = ([_req("ta", i) for i in range(4)]
                + [_req("tb", 10 + i) for i in range(4)]
                + [_req("tc", 20 + i) for i in range(4)])
        before = server.metrics["kernel_dispatches"]
        resps = server.score_batch(reqs)
        # p-a + p-b share {m1,m2} -> one dispatch; p-c -> one dispatch
        assert server.metrics["kernel_dispatches"] - before == 2
        assert [r.predictor for r in resps] == (["p-a"] * 4 + ["p-b"] * 4
                                                + ["p-c"] * 4)

    def test_mixed_batch_matches_singleton_scoring(self):
        """Fused mixed-tenant scores == scoring each request alone."""
        server = _mixed_server()
        reqs = [_req(t, 100 + i) for i, t in enumerate(
            ["ta", "tb", "tc", "tb", "ta", "ta", "tc", "tb"])]
        batch_scores = [r.score for r in server.score_batch(reqs)]
        solo = _mixed_server()
        solo_scores = [solo.score(r).score for r in reqs]
        np.testing.assert_allclose(batch_scores, solo_scores, atol=TOL)

    def test_fused_kernel_matches_jnp_fallback(self):
        reqs = [_req(t, 40 + i) for i, t in enumerate(["ta", "tb", "tc"] * 5)]
        fused = _mixed_server(fused_kernel=True).score_batch(reqs)
        plain = _mixed_server(fused_kernel=False).score_batch(reqs)
        np.testing.assert_allclose([r.score for r in fused],
                                   [r.score for r in plain], atol=TOL)

    def test_latency_measured_per_dispatch(self):
        """Group latencies are per-dispatch: rows of one group share one
        measurement, and no response carries the batch-cumulative time."""
        server = _mixed_server()
        reqs = [_req("ta", 1), _req("tb", 2), _req("tc", 3)]
        resps = server.score_batch(reqs)
        # ta/tb share a dispatch -> identical latency; sum of distinct
        # group latencies can't exceed ~the whole batch wall time, so no
        # group accumulated another group's measurement window.
        assert resps[0].latency_ms == resps[1].latency_ms
        assert resps[0].latency_ms > 0 and resps[2].latency_ms > 0

    def test_swap_transformation_invalidates_bank(self):
        server = _mixed_server()
        req = _req("ta", 5)
        s0 = server.score(req).score
        qs = jnp.linspace(0, 1, 32)
        server.swap_transformation("p-a", QuantileMap(qs, qs ** 3))
        s1 = server.score(req).score
        assert s0 != pytest.approx(s1, abs=1e-9)

    def test_quantile_tracking_batched_per_stream(self):
        server = _mixed_server()
        reqs = [_req("ta", i) for i in range(16)] + [_req("tb", i + 50)
                                                     for i in range(16)]
        server.score_batch(reqs)
        assert server._estimators[("ta", "p-a")].count == 16
        assert server._estimators[("tb", "p-b")].count == 16


class TestServerBatcherWiring:
    def test_mixed_tenants_fill_one_model_group_window(self):
        server = _mixed_server()
        sb = ServerBatcher(server, MicroBatcher(max_batch=4, max_wait_ms=1e9))
        before = server.metrics["kernel_dispatches"]
        assert sb.submit(_req("ta", 0)) is None
        assert sb.submit(_req("tb", 1)) is None
        assert sb.submit(_req("ta", 2)) is None
        resps = sb.submit(_req("tb", 3))     # fills the {m1,m2} window
        assert resps is not None and len(resps) == 4
        assert server.metrics["kernel_dispatches"] - before == 1
        assert sb.pending_count == 0

    def test_drain_flushes_remaining_mixed_window(self):
        server = _mixed_server()
        sb = ServerBatcher(server, MicroBatcher(max_batch=64, max_wait_ms=1e9))
        for i, t in enumerate(["ta", "tb", "tc", "ta"]):
            assert sb.submit(_req(t, i)) is None
        resps = sb.drain()
        assert len(resps) == 4
        assert {r.predictor for r in resps} == {"p-a", "p-b", "p-c"}

    def test_age_trigger_via_poll(self):
        t = [0.0]
        server = _mixed_server()
        sb = ServerBatcher(server, MicroBatcher(max_batch=100, max_wait_ms=5.0,
                                                clock=lambda: t[0]))
        sb.submit(_req("ta", 0))
        assert sb.poll() == []
        t[0] = 0.01
        resps = sb.poll()
        assert len(resps) == 1 and resps[0].predictor == "p-a"
