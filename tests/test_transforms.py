"""Unit + property tests for the composable transformations (paper Sec. 2.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import transforms
from repro.core.transforms import (
    Aggregation,
    PosteriorCorrection,
    QuantileMap,
    posterior_correction,
    posterior_correction_inverse,
    quantile_map,
    score_pipeline,
)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Posterior correction (Eq. 3)
# ---------------------------------------------------------------------------

class TestPosteriorCorrection:
    def test_fixes_endpoints(self):
        y = jnp.array([0.0, 1.0])
        out = posterior_correction(y, 0.2)
        np.testing.assert_allclose(out, [0.0, 1.0], atol=1e-7)

    def test_identity_when_beta_one(self):
        y = jnp.linspace(0, 1, 11)
        np.testing.assert_allclose(posterior_correction(y, 1.0), y, atol=1e-7)

    def test_shrinks_scores_for_undersampled_models(self):
        # beta < 1 (majority class undersampled) inflates raw scores;
        # the correction must deflate them.
        y = jnp.array([0.5, 0.9])
        out = posterior_correction(y, 0.1)
        assert (np.asarray(out) < np.asarray(y)).all()

    def test_matches_paper_formula(self):
        y, beta = 0.7, 0.18
        expected = beta * y / (1 - (1 - beta) * y)
        np.testing.assert_allclose(posterior_correction(jnp.float32(y), beta),
                                   expected, rtol=1e-6)

    def test_roundtrip_with_inverse(self):
        y = jnp.linspace(0.01, 0.99, 23)
        for beta in (0.02, 0.18, 0.5):
            biased = posterior_correction_inverse(y, beta)
            np.testing.assert_allclose(posterior_correction(biased, beta), y,
                                       rtol=1e-5, atol=1e-6)

    @given(
        y=st.floats(0.0, 1.0),
        beta=st.floats(0.01, 1.0),
    )
    @settings(max_examples=200, deadline=None)
    def test_property_output_in_unit_interval_and_monotone(self, y, beta):
        out = float(posterior_correction(jnp.float32(y), beta))
        assert -1e-6 <= out <= 1 + 1e-6
        # monotone: slightly larger input -> >= output
        y2 = min(1.0, y + 1e-3)
        out2 = float(posterior_correction(jnp.float32(y2), beta))
        assert out2 >= out - 1e-5

    def test_exact_prior_shift_inversion(self):
        """T^C exactly inverts the Bayes-rule prior shift from undersampling.

        If p is the true posterior with prior pi, undersampling negatives at
        rate beta yields posterior p' = p / (p + beta (1-p)).  Eq. 3 must map
        p' back to p.
        """
        rng = np.random.default_rng(0)
        p = rng.uniform(0.001, 0.999, size=256).astype(np.float32)
        for beta in (0.02, 0.18):
            p_biased = p / (p + beta * (1 - p))
            rec = np.asarray(posterior_correction(jnp.asarray(p_biased), beta))
            np.testing.assert_allclose(rec, p, rtol=2e-4, atol=2e-5)

    def test_node_identity(self):
        node = PosteriorCorrection.identity()
        y = jnp.linspace(0, 1, 7)
        np.testing.assert_allclose(node(y), y, atol=1e-7)


# ---------------------------------------------------------------------------
# Aggregation (Sec. 2.3.2)
# ---------------------------------------------------------------------------

class TestAggregation:
    def test_uniform_average(self):
        agg = Aggregation.uniform(4)
        scores = jnp.array([[0.1, 0.2, 0.3, 0.4]])
        np.testing.assert_allclose(agg(scores), [0.25], rtol=1e-6)

    def test_weights_self_normalize(self):
        agg = Aggregation(weights=jnp.array([2.0, 2.0]))
        scores = jnp.array([0.0, 1.0])
        np.testing.assert_allclose(agg(scores), 0.5, rtol=1e-6)

    def test_degenerate_weight_selects_expert(self):
        agg = Aggregation(weights=jnp.array([0.0, 1.0, 0.0]))
        scores = jnp.array([0.9, 0.3, 0.8])
        np.testing.assert_allclose(agg(scores), 0.3, rtol=1e-6)

    def test_batched(self):
        agg = Aggregation(weights=jnp.array([1.0, 3.0]))
        scores = jnp.ones((5, 7, 2)) * jnp.array([0.0, 1.0])
        np.testing.assert_allclose(agg(scores), np.full((5, 7), 0.75), rtol=1e-6)


# ---------------------------------------------------------------------------
# Quantile mapping (Eq. 4)
# ---------------------------------------------------------------------------

def _gaussian_quantiles(n, mu, sigma):
    from scipy import stats
    levels = np.linspace(0.001, 0.999, n)
    return levels, stats.norm.ppf(levels, mu, sigma)


class TestQuantileMap:
    def test_identity_map(self):
        qm = QuantileMap.identity(32)
        y = jnp.linspace(0, 1, 17)
        np.testing.assert_allclose(qm(y), y, atol=1e-6)

    def test_matches_paper_interpolation_formula(self):
        qs = jnp.array([0.0, 0.5, 1.0])
        qr = jnp.array([0.0, 0.25, 1.0])
        y = 0.25  # in [q0, q1): out = 0 + (0.25-0)*(0.25-0)/(0.5-0) = 0.125
        np.testing.assert_allclose(quantile_map(jnp.float32(y), qs, qr), 0.125,
                                   rtol=1e-6)

    def test_monotonicity_preserves_ranking(self):
        """The paper's key invariant: ranking (hence recall) unchanged."""
        rng = np.random.default_rng(1)
        src = np.sort(rng.beta(2, 5, 64)).astype(np.float32)
        ref = np.sort(rng.beta(0.8, 8, 64)).astype(np.float32)
        y = jnp.asarray(np.sort(rng.uniform(0, 1, 1000)).astype(np.float32))
        out = np.asarray(quantile_map(y, jnp.asarray(src), jnp.asarray(ref)))
        assert (np.diff(out) >= -1e-6).all()

    def test_distribution_alignment(self):
        """Mapping samples of S through T^Q yields the R distribution."""
        rng = np.random.default_rng(2)
        s_samples = rng.beta(5, 2, 200_000)
        levels = np.linspace(0, 1, 257)
        src_q = np.quantile(s_samples, levels)
        from scipy import stats
        ref_q = stats.beta.ppf(levels, 0.8, 8.0)
        mapped = np.asarray(
            quantile_map(jnp.asarray(s_samples, jnp.float32),
                         jnp.asarray(src_q, jnp.float32),
                         jnp.asarray(ref_q, jnp.float32))
        )
        # Kolmogorov–Smirnov distance between mapped samples and target R
        ks = stats.kstest(mapped, lambda x: stats.beta.cdf(x, 0.8, 8.0)).statistic
        assert ks < 0.01, f"KS distance too large: {ks}"

    def test_out_of_range_clipped_to_reference_support(self):
        qs = jnp.array([0.2, 0.5, 0.8])
        qr = jnp.array([0.1, 0.5, 0.9])
        out = quantile_map(jnp.array([0.0, 1.0]), qs, qr)
        assert float(out[0]) >= 0.1 - 1e-6
        assert float(out[1]) <= 0.9 + 1e-6

    def test_fit_from_samples(self):
        rng = np.random.default_rng(3)
        samples = rng.beta(2, 8, 50_000)
        ref = jnp.linspace(0, 1, 128)
        qm = QuantileMap.fit(samples, ref)
        mapped = np.asarray(qm(jnp.asarray(samples, jnp.float32)))
        # mapped distribution should be ~uniform
        hist, _ = np.histogram(mapped, bins=10, range=(0, 1))
        props = hist / len(mapped)
        np.testing.assert_allclose(props, 0.1, atol=0.02)

    @given(st.integers(3, 200), st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_property_monotone_any_tables(self, n, seed):
        rng = np.random.default_rng(seed)
        src = np.sort(rng.uniform(0, 1, n)).astype(np.float32)
        ref = np.sort(rng.uniform(0, 1, n)).astype(np.float32)
        y = np.sort(rng.uniform(0, 1, 100)).astype(np.float32)
        out = np.asarray(quantile_map(jnp.asarray(y), jnp.asarray(src), jnp.asarray(ref)))
        assert (np.diff(out) >= -1e-5).all()
        assert (out >= ref[0] - 1e-6).all() and (out <= ref[-1] + 1e-6).all()


# ---------------------------------------------------------------------------
# Full Eq. 2 pipeline
# ---------------------------------------------------------------------------

class TestScorePipeline:
    def test_composition_matches_stagewise(self):
        rng = np.random.default_rng(4)
        raw = jnp.asarray(rng.uniform(0, 1, (32, 3)).astype(np.float32))
        betas = jnp.array([0.18, 0.18, 0.02])
        weights = jnp.array([1.0, 1.0, 2.0])
        qs = jnp.asarray(np.sort(rng.uniform(0, 1, 64)).astype(np.float32))
        qr = jnp.asarray(np.sort(rng.uniform(0, 1, 64)).astype(np.float32))

        fused = score_pipeline(raw, betas, weights, qs, qr)

        stage = posterior_correction(raw, betas)
        stage = Aggregation(weights)(stage)
        stage = quantile_map(stage, qs, qr)
        np.testing.assert_allclose(fused, stage, rtol=1e-6, atol=1e-7)

    def test_jit_and_grad_compatible(self):
        # The pipeline must live inside jitted serving steps.
        raw = jnp.full((8, 2), 0.5)
        betas = jnp.array([0.2, 0.3])
        weights = jnp.array([1.0, 1.0])
        qs = jnp.linspace(0, 1, 16)
        qr = jnp.linspace(0, 1, 16) ** 2
        f = jax.jit(score_pipeline)
        out = f(raw, betas, weights, qs, qr)
        assert out.shape == (8,)
        assert not np.isnan(np.asarray(out)).any()
